//! Analytical throughput model for the SSD-resident KV store (Fig 8).
//!
//! The paper evaluates a 5TB store (80G × 64B items, load 0.7) with the
//! blocked-Cuckoo design of [`crate::kvstore::cuckoo`] at production scale
//! — far beyond what any functional engine can execute — so, exactly as in
//! the paper, achievable throughput is *modeled*: per-operation SSD/host/
//! DRAM costs are derived from the engine's mechanism (1.5 bucket reads
//! per uncached GET, WAL-consolidated read-modify-writes per PUT) and
//! bounded by the platform's calibrated resources:
//!
//!   X = min( usable-SSD-IOPS / ssd-IOs-per-op,
//!            host-IOPS       / host-IOs-per-op,
//!            DRAM bandwidth  / bytes-per-op )
//!
//! Cache hit rates come from the log-normal access-interval profile
//! (strong σ=1.2 / weak σ=0.4 locality, Sec VII-A); WAL consolidation is
//! estimated from the same profile via a collision model.

use crate::config::{IoMix, PlatformConfig, SsdConfig};
use crate::model::queueing::{self, LatencyTargets};
use crate::workload::lognormal::LognormalProfile;

/// Fig 8 scenario parameters.
#[derive(Clone, Debug)]
pub struct KvScenario {
    /// Total unique items (paper: 80e9).
    pub n_items: f64,
    /// Item size (paper: 64B).
    pub l_kv: u32,
    /// Cuckoo load factor (paper: 0.7).
    pub load_factor: f64,
    /// GET fraction of operations (e.g. 1.0, 0.9, 0.7, 0.5).
    pub get_frac: f64,
    /// Fraction of PUTs that are inserts (paper: 0.2; the rest update).
    pub insert_frac: f64,
    /// Locality: σ of the log-normal access-interval law
    /// (strong 1.2 / weak 0.4).
    pub sigma: f64,
    /// WAL flush batch size in entries.
    pub wal_batch: f64,
    /// SSD utilization cap for tail latency (paper: 0.7).
    pub rho_cap: f64,
}

impl KvScenario {
    pub fn paper_default(get_frac: f64, sigma: f64) -> Self {
        KvScenario {
            n_items: 80e9,
            l_kv: 64,
            load_factor: 0.7,
            get_frac,
            insert_frac: 0.2,
            sigma,
            wal_batch: 64.0 * 1024.0,
            rho_cap: 0.7,
        }
    }

    /// Bucket (block) size implied by the device class.
    pub fn bucket_bytes(&self, ssd: &SsdConfig) -> u32 {
        match ssd.ecc {
            crate::config::EccArch::FineGrained512 => 512,
            crate::config::EccArch::Coarse4k => 4096,
        }
    }
}

/// Per-op cost breakdown + the resulting bound (the Fig 8 y-value).
#[derive(Clone, Copy, Debug)]
pub struct KvThroughput {
    /// Cache hit rate over GET traffic.
    pub hit_rate: f64,
    /// SSD I/Os per operation (reads + writes, amortized).
    pub ssd_ios_per_op: f64,
    /// Host-DRAM bytes moved per operation.
    pub dram_bytes_per_op: f64,
    /// Ops/s bounds by resource.
    pub bound_ssd: f64,
    pub bound_host: f64,
    pub bound_dram: f64,
    /// min of the three.
    pub achievable: f64,
    pub limiter: &'static str,
}

/// WAL consolidation factor: expected batch entries per distinct bucket.
/// Updates land on buckets with the same popularity skew as GETs; with
/// batch W spread over hot buckets, collisions grow with locality. We
/// estimate via the profile's rate concentration: the fraction of update
/// traffic hitting the hottest `W` buckets collapses into single RMWs.
fn consolidation_factor(profile: &LognormalProfile, n_buckets: f64, batch: f64) -> f64 {
    // Probability a batch entry hits a "hot" bucket (top h fraction of
    // buckets carrying q(h) of traffic). Choose h = batch/n_buckets: hot
    // buckets receive >=1 expected entry; entries there consolidate.
    let h = (batch / n_buckets).clamp(1e-12, 1.0);
    let t = profile.t_for_capacity(h * profile.n_blk * profile.l_blk as f64);
    let q = profile.psi_cached(t) / profile.total_bps(); // traffic share of hot set
    // Hot entries per hot bucket:
    let hot_entries = q * batch;
    let hot_buckets = h * n_buckets;
    let per_bucket = (hot_entries / hot_buckets).max(1.0);
    // Blend: hot traffic consolidates by per_bucket, cold traffic ~1.
    1.0 / ((q / per_bucket) + (1.0 - q))
}

/// Evaluate the Fig 8 model for one (platform, device, DRAM capacity).
pub fn kv_throughput(
    sc: &KvScenario,
    platform: &PlatformConfig,
    ssd: &SsdConfig,
    dram_capacity_bytes: f64,
) -> KvThroughput {
    let l_blk = sc.bucket_bytes(ssd) as u64;

    // --- cache hit rate from the item-level access-interval profile -----
    // Item-level profile: n_items blocks of l_kv bytes; absolute rate is
    // irrelevant for hit rates (only the shape matters), so normalize to
    // 1 B/s per... use total=1.0.
    let profile = LognormalProfile::calibrated(1.0, sc.sigma, sc.n_items, sc.l_kv as u64);
    let cache_items_bytes = dram_capacity_bytes.min(sc.n_items * sc.l_kv as f64);
    let t_cache = profile.t_for_capacity(cache_items_bytes);
    let hit_rate = (profile.psi_cached(t_cache) / profile.total_bps()).clamp(0.0, 1.0);

    // --- per-op SSD I/O costs -------------------------------------------
    let put_frac = 1.0 - sc.get_frac;
    // GET miss: expected 1.5 bucket reads (2-choice probing).
    let get_reads = (1.0 - hit_rate) * 1.5;
    // PUT: WAL append amortized over entries packed per block…
    let wal_writes_per_put = 1.0 / (l_blk as f64 / sc.l_kv as f64).max(1.0);
    // …plus the consolidated bucket read-modify-write at flush:
    let n_buckets = sc.n_items / sc.load_factor / (l_blk as f64 / sc.l_kv as f64);
    let cf = consolidation_factor(&profile, n_buckets, sc.wal_batch);
    let rmw_per_put = (1.0 + 1.0) / cf; // 1 read + 1 write per distinct bucket
    // inserts additionally probe the second bucket + displacement writes
    let insert_extra = sc.insert_frac * (0.5 + 0.05);
    let put_ios = wal_writes_per_put + rmw_per_put + insert_extra;
    let ssd_ios_per_op = sc.get_frac * get_reads + put_frac * put_ios;

    // --- per-op DRAM traffic (zero-copy: miss = DMA + CPU read) ---------
    let get_bytes = hit_rate * sc.l_kv as f64
        + (1.0 - hit_rate) * 1.5 * 2.0 * l_blk as f64;
    let put_bytes = sc.l_kv as f64 // WAL buffer write
        + rmw_per_put * 2.0 * l_blk as f64;
    let dram_bytes_per_op = sc.get_frac * get_bytes + put_frac * put_bytes;

    // --- resource bounds --------------------------------------------------
    let mix = IoMix::new(
        if put_frac == 0.0 { f64::INFINITY } else { sc.get_frac / put_frac },
        3.0,
    );
    let peak = crate::model::ssd::ssd_peak_iops(ssd, l_blk, mix).effective;
    let usable_ssd = sc.rho_cap * peak * platform.n_ssd as f64;
    let _ = queueing::LatencyTargets::none();
    let bound_ssd = if ssd_ios_per_op > 0.0 {
        usable_ssd / ssd_ios_per_op
    } else {
        f64::INFINITY
    };
    let bound_host = if ssd_ios_per_op > 0.0 {
        platform.proc_iops_peak / ssd_ios_per_op
    } else {
        f64::INFINITY
    };
    let bound_dram = platform.dram_bw_total / dram_bytes_per_op.max(1.0);
    let achievable = bound_ssd.min(bound_host).min(bound_dram);
    let limiter = if achievable == bound_ssd {
        "ssd"
    } else if achievable == bound_host {
        "host"
    } else {
        "dram-bw"
    };
    KvThroughput {
        hit_rate,
        ssd_ios_per_op,
        dram_bytes_per_op,
        bound_ssd,
        bound_host,
        bound_dram,
        achievable,
        limiter,
    }
}

/// Convenience: latency-target plumbing retained for API parity.
pub fn targets_for_cap(_rho: f64) -> LatencyTargets {
    LatencyTargets::none()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NandKind, PlatformKind};

    fn gpu() -> PlatformConfig {
        PlatformConfig::preset(PlatformKind::GpuGddr)
    }
    fn cpu() -> PlatformConfig {
        PlatformConfig::preset(PlatformKind::CpuDdr)
    }
    fn sn() -> SsdConfig {
        SsdConfig::storage_next(NandKind::Slc)
    }
    fn nr() -> SsdConfig {
        SsdConfig::normal(NandKind::Slc)
    }
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;

    #[test]
    fn gpu_sn_read_heavy_sustains_100m_ops() {
        // Sec VII-A headline: "On read-heavy mixes, GPU+SN sustains 100+
        // Mops/s, comparable to in-memory KV stores such as FASTER."
        let sc = KvScenario::paper_default(0.9, 1.2);
        let t = kv_throughput(&sc, &gpu(), &sn(), 256.0 * GB);
        assert!(
            t.achievable > 100e6,
            "GPU+SN 90:10 strong locality: {:.1} Mops/s",
            t.achievable / 1e6
        );
    }

    #[test]
    fn cpu_is_host_limited_with_storage_next() {
        // "Switching to a CPU with the same Storage-Next SSDs shifts the
        // bottleneck to host IOPS."
        let sc = KvScenario::paper_default(0.9, 1.2);
        let t = kv_throughput(&sc, &cpu(), &sn(), 256.0 * GB);
        assert_eq!(t.limiter, "host");
        let g = kv_throughput(&sc, &gpu(), &sn(), 256.0 * GB);
        assert!(g.achievable > t.achievable * 1.5, "GPU should lead CPU");
    }

    #[test]
    fn normal_ssd_is_device_limited_cpu_equals_gpu() {
        // "With normal SSDs the system is device-limited, so CPU and GPU
        // collapse into a single curve."
        let sc = KvScenario::paper_default(0.9, 1.2);
        for cap in [64.0 * GB, 256.0 * GB] {
            let c = kv_throughput(&sc, &cpu(), &nr(), cap);
            let g = kv_throughput(&sc, &gpu(), &nr(), cap);
            assert_eq!(c.limiter, "ssd");
            assert!(
                (c.achievable - g.achievable).abs() / g.achievable < 0.05,
                "CPU {:.1}M vs GPU {:.1}M",
                c.achievable / 1e6,
                g.achievable / 1e6
            );
        }
    }

    #[test]
    fn strong_locality_gains_more_from_dram() {
        // "strong locality extracts more value from added DRAM capacity"
        let strong = KvScenario::paper_default(0.9, 1.2);
        let weak = KvScenario::paper_default(0.9, 0.4);
        let gain = |sc: &KvScenario| {
            let small = kv_throughput(sc, &gpu(), &sn(), 32.0 * GB).achievable;
            let large = kv_throughput(sc, &gpu(), &sn(), 512.0 * GB).achievable;
            large / small
        };
        assert!(
            gain(&strong) > gain(&weak),
            "strong {:.2}x vs weak {:.2}x",
            gain(&strong),
            gain(&weak)
        );
    }

    #[test]
    fn write_share_reduces_throughput() {
        // "as the write share grows … reducing the operational throughput"
        let mut prev = f64::INFINITY;
        for gf in [1.0, 0.9, 0.7, 0.5] {
            let sc = KvScenario::paper_default(gf, 1.2);
            let t = kv_throughput(&sc, &gpu(), &sn(), 128.0 * GB);
            assert!(
                t.achievable < prev * 1.001,
                "GET:{gf}: {:.1}M !< prev {:.1}M",
                t.achievable / 1e6,
                prev / 1e6
            );
            prev = t.achievable;
        }
    }

    #[test]
    fn throughput_monotone_in_dram() {
        let sc = KvScenario::paper_default(0.9, 1.2);
        let mut prev = 0.0;
        for cap in [16.0, 64.0, 128.0, 256.0, 512.0] {
            let t = kv_throughput(&sc, &gpu(), &sn(), cap * GB);
            assert!(t.achievable + 1.0 >= prev, "cap {cap}GB regressed");
            prev = t.achievable;
        }
    }

    #[test]
    fn hit_rate_sane() {
        let sc = KvScenario::paper_default(0.9, 1.2);
        let t = kv_throughput(&sc, &gpu(), &sn(), 512.0 * GB);
        // 512GB of 5TB is 10% of items; strong locality should catch well
        // above 10% of traffic, below 100%.
        assert!(t.hit_rate > 0.15 && t.hit_rate < 0.95, "hit {:.2}", t.hit_rate);
    }

    #[test]
    fn storage_next_beats_normal_2x_plus() {
        let sc = KvScenario::paper_default(0.9, 1.2);
        let s = kv_throughput(&sc, &gpu(), &sn(), 128.0 * GB);
        let n = kv_throughput(&sc, &gpu(), &nr(), 128.0 * GB);
        assert!(
            s.achievable > 2.0 * n.achievable,
            "SN {:.1}M !> 2x NR {:.1}M",
            s.achievable / 1e6,
            n.achievable / 1e6
        );
    }
}
