//! Case study 1 (Sec VII-A): the SSD-resident blocked-Cuckoo KV store.
//!
//! * [`cuckoo`] — the 2-choice blocked hash table over an abstract block
//!   store (no DRAM-resident index or metadata).
//! * [`wal`] — SSD-resident write-ahead log with bucket-consolidated
//!   commits.
//! * [`engine`] — the assembled functional engine (GET/PUT over any
//!   [`cuckoo::BlockStore`]).
//! * [`backed`] — a block store that charges every bucket access and WAL
//!   append to a [`crate::storage::StorageBackend`], putting the engine's
//!   traffic on the analytic-model or MQSim-Next device path — and, when
//!   that backend is a [`crate::storage::TieredBackend`], under the same
//!   economics-governed DRAM tier that serves the ANN stage-2 path.
//!   (The engine's old ad-hoc `KvCache` CLOCK cache is retired: DRAM
//!   placement is the storage tier's job now, one admission policy for
//!   both workloads; the CLOCK second-chance core lives on as the tier's
//!   eviction machinery.)
//! * [`analysis`] — the paper-scale throughput model behind Fig 8.

pub mod analysis;
pub mod backed;
pub mod cuckoo;
pub mod engine;
pub mod wal;

pub use analysis::{kv_throughput, KvScenario, KvThroughput};
pub use backed::BackedStore;
pub use cuckoo::{BlockStore, CuckooParams, KvPair, MemStore};
pub use engine::{IoCounted, KvEngine};
