//! DRAM hot-KV-pair cache (Sec VII-A): "we dedicate all available DRAM to
//! caching individual hot KV pairs" — a CLOCK (second-chance) cache keyed
//! by key, approximating LRU at O(1) per access without list churn.

use std::collections::HashMap;

/// CLOCK cache of fixed entry capacity.
pub struct KvCache {
    cap: usize,
    map: HashMap<u64, usize>, // key -> slot
    slots: Vec<Slot>,
    hand: usize,
    pub hits: u64,
    pub misses: u64,
}

#[derive(Clone, Copy)]
struct Slot {
    key: u64,
    value: u64,
    referenced: bool,
    occupied: bool,
}

impl KvCache {
    /// Capacity in entries; size from DRAM bytes / l_KV upstream.
    pub fn new(cap: usize) -> Self {
        KvCache {
            cap,
            map: HashMap::with_capacity(cap),
            slots: vec![
                Slot { key: 0, value: 0, referenced: false, occupied: false };
                cap.max(1)
            ],
            hand: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn get(&mut self, key: u64) -> Option<u64> {
        match self.map.get(&key) {
            Some(&i) => {
                self.hits += 1;
                self.slots[i].referenced = true;
                Some(self.slots[i].value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert/update without counting as an access miss.
    pub fn put(&mut self, key: u64, value: u64) {
        if self.cap == 0 {
            return;
        }
        if let Some(&i) = self.map.get(&key) {
            self.slots[i].value = value;
            self.slots[i].referenced = true;
            return;
        }
        let i = self.evict_slot();
        if self.slots[i].occupied {
            self.map.remove(&self.slots[i].key);
        }
        self.slots[i] = Slot { key, value, referenced: true, occupied: true };
        self.map.insert(key, i);
    }

    pub fn invalidate(&mut self, key: u64) {
        if let Some(i) = self.map.remove(&key) {
            self.slots[i].occupied = false;
            self.slots[i].referenced = false;
        }
    }

    fn evict_slot(&mut self) -> usize {
        loop {
            let i = self.hand;
            self.hand = (self.hand + 1) % self.cap;
            if !self.slots[i].occupied {
                return i;
            }
            if self.slots[i].referenced {
                self.slots[i].referenced = false;
            } else {
                return i;
            }
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Zipf};

    #[test]
    fn basic_get_put() {
        let mut c = KvCache::new(4);
        assert_eq!(c.get(1), None);
        c.put(1, 10);
        assert_eq!(c.get(1), Some(10));
        c.put(1, 11);
        assert_eq!(c.get(1), Some(11));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn eviction_respects_capacity() {
        let mut c = KvCache::new(3);
        for k in 0..10 {
            c.put(k, k);
        }
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn clock_keeps_hot_keys() {
        let mut c = KvCache::new(8);
        for k in 0..8 {
            c.put(k, k);
        }
        // touch keys 0..4 repeatedly, then stream cold keys through
        for _ in 0..3 {
            for k in 0..4 {
                c.get(k);
            }
        }
        for k in 100..108 {
            c.put(k, k);
            for h in 0..4 {
                c.get(h); // keep re-referencing hot set
            }
        }
        let hot_alive = (0..4).filter(|&k| c.get(k).is_some()).count();
        assert!(hot_alive >= 3, "hot keys evicted: {hot_alive}/4 alive");
    }

    #[test]
    fn invalidate_removes() {
        let mut c = KvCache::new(4);
        c.put(5, 50);
        c.invalidate(5);
        assert_eq!(c.get(5), None);
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn zero_capacity_is_noop() {
        let mut c = KvCache::new(0);
        c.put(1, 1);
        assert_eq!(c.get(1), None);
    }

    #[test]
    fn zipf_hit_rate_grows_with_capacity() {
        let z = Zipf::new(10_000, 1.1);
        let mut rng = Rng::new(9);
        let mut small = KvCache::new(100);
        let mut large = KvCache::new(2_000);
        for _ in 0..100_000 {
            let k = z.sample(&mut rng) as u64;
            for c in [&mut small, &mut large] {
                if c.get(k).is_none() {
                    c.put(k, k);
                }
            }
        }
        assert!(
            large.hit_rate() > small.hit_rate() + 0.1,
            "large {:.2} vs small {:.2}",
            large.hit_rate(),
            small.hit_rate()
        );
        assert!(small.hit_rate() > 0.2, "zipf should give decent hits");
    }
}
