//! Perf-smoke harness (`fivemin smoke`): a short serving-scenario matrix
//! — `{mem, sim} × {spec, merge, adaptive} × shards ∈ {1, 2}`, plus
//! DRAM-tier cells `{mem, sim} × {clock, breakeven} × {2 MB, 8 MB}`,
//! reactor-seam cells `{mem, sim} × {spec, merge, adaptive}` served through
//! `Router::partitioned_reactor`, and selective-routing cells
//! `{mem, sim} × {route=all, route=topm:2}` on a 4-shard clustered corpus
//! — measured end to end and gated against a checked-in baseline, so a
//! regression in the router protocols, the adaptive control loop, the
//! tier's accounting, the completion-driven serving core, or the
//! affinity router's fan-out cut is caught mechanically in CI rather
//! than by eyeball.
//!
//! Per cell the harness reports stage-2 reads per query (submitted and
//! post-tier device), the p50/p99 end-to-end (merged-answer) latency,
//! the adaptive controller's merge share, and the tier hit rate. The
//! JSON artifact (`results/bench_smoke.json`) is uploaded by the
//! `bench-smoke` CI job; the gate compares against
//! `rust/benches/common/smoke_baseline.json`:
//!
//! * **`reads_per_query` is gated** (default ±25%). It is deterministic —
//!   the equivalence suite pins `N×k` for speculative and `k` for
//!   after-merge — so any drift is a real protocol/accounting change.
//! * **Adaptive cells are gated relative to the same run's static
//!   cells**: the controller may legitimately sit anywhere between the
//!   merge and spec read costs depending on measured load, so the bound
//!   is `merge×(1−tol) ≤ adaptive ≤ spec×(1+tol)`, not a fixed number.
//! * **Reactor cells are gated relative to the same run's threaded
//!   peer**: the reactor seam reuses the threaded seam's merge/promote/
//!   rank helpers, so its submitted reads per query must match the
//!   threaded cell for static fetch modes (adaptive reactor cells are
//!   bounded by the threaded static peers like any adaptive cell), and
//!   the baseline's `reactor_cells` list pins the scenario set.
//! * **Tier cells are gated relative to their untiered peer** too: the
//!   tier must never *increase* device reads
//!   (`device ≤ peer×(1+tol)`), its exact accounting
//!   (`hits + device reads == submitted reads`) is enforced when the
//!   cell runs, and the baseline's `tier_cells` list pins the scenario
//!   set so a silently dropped tier cell fails the gate. The absolute
//!   hit rate is reported, not gated — it shifts with any intentional
//!   change to the workload shape, while the invariants above cannot.
//! * **Route cells are gated relative to the same run's `route=all`
//!   peer**: the `topm` cell's stage-1 legs/query must stay under
//!   M plus the deterministic probe quota (and a bounded escalation
//!   allowance — a predictor that escalates on most queries is not
//!   cutting work), its p99 must be no worse than the full-fan-out peer
//!   (with same-run headroom), and the probe-measured live recall must
//!   clear a floor. The baseline's `route_cells` list pins the set.
//! * **Latencies are reported, not gated by default** (shared CI runners
//!   jitter far more than 25%); a baseline cell may opt in to an absolute
//!   ceiling via `p99_budget_us`.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, ensure, Context, Result};

use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::{
    AdaptiveConfig, AffinityPredictor, Coordinator, FetchMode, ReactorConfig, RouteConfig,
    RouteSpec, Router, ServingCorpus,
};
use crate::runtime::default_artifacts_dir;
use crate::storage::{BackendSpec, TierRule, TierSpec};
use crate::util::json::Json;
use crate::util::rng::{Rng, Zipf};
use crate::util::stats::Samples;
use crate::util::table::Table;

/// Artifact/baseline schema tag (bump on breaking shape changes).
/// v2: tier cells + device_reads_per_query / tier_hits / tier_hit_rate.
/// v3: per-cell `serve` seam field + reactor cells pinned by
/// `reactor_cells`.
/// v4: per-cell `stage1_legs_per_query` + selective-routing cells
/// (`route` segment/field) pinned by `route_cells`.
pub const SCHEMA: &str = "fivemin-bench-smoke/v4";

/// Schema tag for the perf-trajectory artifact (`BENCH_SMOKE.json` at the
/// repo root): the compact per-cell series future PRs diff against.
pub const TRAJECTORY_SCHEMA: &str = "fivemin-bench-trajectory/v1";

/// Shard count for the selective-routing cells (the fan-out cut needs
/// room to show: M = N/2 on 4 shards halves stage-1 work).
const ROUTE_SHARDS: usize = 4;

/// Predicted-set size for the `route=topm` cells.
const ROUTE_M: usize = 2;

/// Probe cadence for the route cells (kept explicit so the gate's probe
/// quota and the measurement agree).
const ROUTE_PROBE_EVERY: u64 = 32;

/// Escalation allowance in the route gate, as a fraction of queries: a
/// predictor may escalate a minority of queries and still win; one that
/// escalates more than this is not cutting work and should fail.
const ROUTE_ESC_ALLOWANCE: f64 = 0.25;

/// p99 headroom for the route-vs-full-fan-out comparison. Same-run
/// relative bounds jitter less than absolute budgets, but shared runners
/// still wobble; the point is catching a tail *regression*, not a tie.
const ROUTE_P99_HEADROOM: f64 = 0.5;

/// Smoke-level floor on probe-measured live recall. The strict 0.95
/// floor is pinned by the seeded equivalence suite; the smoke gate
/// leaves slack for its handful of probe samples.
const ROUTE_RECALL_FLOOR: f64 = 0.9;

/// Reference arrival rate (accesses/s) for the smoke tier cells: sized so
/// the break-even bar bites within a 48-query cell (only the hottest
/// zipf targets demonstrate reuse under it), keeping the clock-vs-
/// breakeven contrast visible at smoke scale.
const TIER_SMOKE_RATE: f64 = 100.0;

/// Default queries per cell. Enough for the adaptive controller (tuned to
/// an 8-query window here) to sample several windows — and for the route
/// cells' probe cadence to fire more than once — small enough that the
/// whole 30-cell matrix (12 static + 8 tier + 6 reactor + 4 route) stays
/// a smoke test.
pub const DEFAULT_QUERIES: usize = 48;

/// One measured (backend, fetch mode, shard count[, tier][, seam])
/// scenario.
#[derive(Clone, Debug)]
pub struct SmokeCell {
    /// Storage backend behind every partition worker (`mem` | `sim`).
    pub backend: &'static str,
    pub fetch: FetchMode,
    /// Corpus shards = partition workers.
    pub shards: usize,
    /// DRAM-tier label (e.g. `dram2:clock`) when the cell runs the tier.
    pub tier: Option<String>,
    /// Serving seam: `threads` (merger + finisher threads) or `reactor`
    /// (completion-driven event loop).
    pub serve: &'static str,
    /// Routing spec label (`all` | `topm:M`) when the cell runs the
    /// affinity router; `None` for the legacy unrouted cells.
    pub route: Option<String>,
    pub queries: usize,
    /// Stage-2 reads *submitted* per query (coordinator-side counter,
    /// settled against the backend snapshot). With a tier, each lands on
    /// the device or in DRAM.
    pub reads_per_query: f64,
    /// Post-tier *device* stage-2 reads per query (== `reads_per_query`
    /// for untiered cells).
    pub device_reads_per_query: f64,
    /// Tier hits (0 for untiered cells).
    pub tier_hits: u64,
    pub tier_hit_rate: f64,
    /// End-to-end merged-answer latency percentiles (µs).
    pub p50_us: f64,
    pub p99_us: f64,
    /// Fraction of queries the adaptive controller dispatched as
    /// fetch-after-merge (0 for static cells).
    pub merge_share: f64,
    /// Stage-1 search/reduce legs dispatched per query (escalation legs
    /// included). Exact for every partition cell: N unrouted, ≈M routed.
    pub stage1_legs_per_query: f64,
    /// Full-fan-out probe queries (route cells only; 0 otherwise).
    pub probes: u64,
    /// Escalated queries (route cells only; 0 otherwise).
    pub escalations: u64,
    /// Probe-measured live recall (1.0 when nothing was probed).
    pub probe_recall: f64,
}

impl SmokeCell {
    /// Stable cell key used by the baseline file. Threaded untiered cells
    /// keep the historical 3-segment key; tier and reactor cells append
    /// their dimension, so existing baseline keys never move.
    pub fn key(&self) -> String {
        let mut key = format!("{}/{}/{}", self.backend, self.fetch.name(), self.shards);
        if let Some(t) = &self.tier {
            key.push('/');
            key.push_str(t);
        }
        if let Some(r) = &self.route {
            key.push_str("/route=");
            key.push_str(r);
        }
        if self.serve == "reactor" {
            key.push_str("/reactor");
        }
        key
    }
}

fn run_cell(
    backend: &'static str,
    fetch: FetchMode,
    shards: usize,
    queries: usize,
    tier: Option<TierSpec>,
    serve: &'static str,
    route: Option<RouteSpec>,
) -> Result<SmokeCell> {
    // Route cells serve a *clustered* corpus (clusters aligned with the
    // partition cut): selective routing is only meaningful when shards
    // differ — on an iid corpus every shard is equally relevant and a
    // top-M cut necessarily loses recall.
    let corpus = Arc::new(if route.is_some() {
        ServingCorpus::synthetic_clustered(shards, shards, 0x5140C + shards as u64)
    } else {
        ServingCorpus::synthetic(shards, 0x5140C + shards as u64)
    });
    let device = match backend {
        "mem" => BackendSpec::Mem,
        "sim" => BackendSpec::small_sim(4096),
        other => return Err(anyhow!("unknown smoke backend '{other}'")),
    };
    let spec = match &tier {
        Some(t) => device.tiered(t.clone()),
        None => device,
    };
    let parts = corpus.partitions(shards)?;
    // the predictor sketches each partition's centroid before the parts
    // move into their Coordinators
    let pred = match route {
        Some(spec) => Some(Arc::new(AffinityPredictor::from_partitions(
            &parts,
            RouteConfig { spec, probe_every: ROUTE_PROBE_EVERY, ..RouteConfig::default() },
        )?)),
        None => None,
    };
    let workers = parts
        .into_iter()
        .map(|part| {
            let spec = spec.clone().for_capacity(part.n as u64);
            Coordinator::start(
                default_artifacts_dir(),
                Arc::new(part),
                BatchPolicy::default(),
                spec,
            )
        })
        .collect::<Result<Vec<_>>>()?;
    // small window so the controller actually samples within a
    // smoke-sized run; rare refresh keeps probes out of the tail
    let acfg = AdaptiveConfig { window: 8, refresh: 32, ..AdaptiveConfig::default() };
    let router = match (serve, pred) {
        ("reactor", Some(p)) => Router::partitioned_reactor_routed(
            workers,
            fetch,
            ReactorConfig { adaptive: acfg, ..ReactorConfig::default() },
            p,
        )?,
        ("reactor", None) => Router::partitioned_reactor(
            workers,
            fetch,
            ReactorConfig { adaptive: acfg, ..ReactorConfig::default() },
        )?,
        ("threads", Some(p)) => Router::partitioned_routed(workers, fetch, p)?,
        ("threads", None) => match fetch {
            FetchMode::Adaptive => Router::partitioned_adaptive(workers, acfg)?,
            mode => Router::partitioned_with(workers, mode)?,
        },
        (other, _) => return Err(anyhow!("unknown serve seam '{other}'")),
    };
    // one shared query stream per (backend, shards): every fetch mode
    // serves identical queries, so cells differ only in protocol. Tier
    // and route cells draw zipf-popular targets instead — reuse (tier)
    // and skew (routing's reason to exist) are what those cells measure.
    let mut rng = Rng::new(0x5140C);
    let zipf = Zipf::new(corpus.n, 1.1);
    let pending: Vec<_> = (0..queries)
        .map(|_| {
            let target = if tier.is_some() || route.is_some() {
                zipf.sample(&mut rng).min(corpus.n - 1)
            } else {
                rng.below(corpus.n as u64) as usize
            };
            router.submit(corpus.query_near(target, 0.02, &mut rng))
        })
        .collect();
    let mut lat = Samples::new();
    for rx in pending {
        let res = rx
            .recv()
            .map_err(|_| anyhow!("router worker died"))?
            .map_err(|e| anyhow!(e))?;
        lat.push(res.latency.as_nanos() as f64);
    }
    let st = router.settled_stats(Duration::from_secs(10));
    let merge_share = router.adaptive_report().map(|r| r.merge_share()).unwrap_or(0.0);
    let snap = st.storage.as_ref().ok_or_else(|| anyhow!("missing storage snapshot"))?;
    let (tier_hits, tier_hit_rate) = snap
        .stats
        .tier
        .as_ref()
        .map(|t| (t.stage2_hits, t.hit_rate()))
        .unwrap_or((0, 0.0));
    if tier.is_some() {
        // The tier's accounting invariant, enforced at measurement time:
        // every submitted stage-2 read lands on the device or in DRAM.
        ensure!(
            snap.stats.stage2_reads + tier_hits == st.ssd_reads,
            "tier accounting broken: {} device + {} hits != {} submitted",
            snap.stats.stage2_reads,
            tier_hits,
            st.ssd_reads
        );
    }
    Ok(SmokeCell {
        backend,
        fetch,
        shards,
        tier: tier.as_ref().map(|t| t.label()),
        serve,
        route: route.as_ref().map(|s| s.name()),
        queries,
        reads_per_query: st.ssd_reads as f64 / queries.max(1) as f64,
        device_reads_per_query: snap.stats.stage2_reads as f64 / queries.max(1) as f64,
        tier_hits,
        tier_hit_rate,
        p50_us: lat.percentile(0.5) / 1e3,
        p99_us: lat.percentile(0.99) / 1e3,
        merge_share,
        stage1_legs_per_query: st.routed_shards as f64 / queries.max(1) as f64,
        probes: st.probes,
        escalations: st.escalations,
        probe_recall: st.probe_recall,
    })
}

/// Run the full scenario matrix. Every cell serves `queries` queries
/// open-loop through a partitioned router with one worker per corpus
/// shard; tier cells add a DRAM tier in front of each worker's device.
pub fn run_matrix(queries: usize) -> Result<Vec<SmokeCell>> {
    let mut cells = Vec::new();
    for backend in ["mem", "sim"] {
        for shards in [1usize, 2] {
            for fetch in [FetchMode::Speculative, FetchMode::AfterMerge, FetchMode::Adaptive] {
                cells.push(run_cell(backend, fetch, shards, queries, None, "threads", None)?);
            }
        }
    }
    // DRAM-tier cells: {clock, breakeven} at two capacities, single
    // partition, speculative fetch (the untiered mem|sim/spec/1 cells are
    // the relative-gate peers).
    for backend in ["mem", "sim"] {
        for mb in [2u64, 8] {
            for rule in [TierRule::Clock, TierRule::Breakeven] {
                let tier = TierSpec { rate: TIER_SMOKE_RATE, ..TierSpec::new(mb, rule, 4096) };
                cells.push(run_cell(
                    backend,
                    FetchMode::Speculative,
                    1,
                    queries,
                    Some(tier),
                    "threads",
                    None,
                )?);
            }
        }
    }
    // Reactor-seam cells: the completion-driven event loop over the same
    // 2-shard scenarios (the threaded mem|sim/{spec,merge,adaptive}/2
    // cells are the relative-gate peers). Speculative is here since the
    // async storage rework: it drives the workers' full-search submit/
    // sweep path, so a regression in the non-blocking completion flow
    // shows up as drifted reads per query against the threaded peer.
    for backend in ["mem", "sim"] {
        for fetch in [FetchMode::Speculative, FetchMode::AfterMerge, FetchMode::Adaptive] {
            cells.push(run_cell(backend, fetch, 2, queries, None, "reactor", None)?);
        }
    }
    // Selective-routing cells: a 4-shard clustered corpus served
    // fetch-after-merge, once with full fan-out (`route=all`, the gate's
    // same-run peer) and once with the affinity router cutting stage-1
    // fan-out to top-M (`route=topm:2`). Zipf traffic keeps a skewed
    // cluster heat, which is the regime the predictor exists for.
    for backend in ["mem", "sim"] {
        for spec in [RouteSpec::All, RouteSpec::TopM(ROUTE_M)] {
            cells.push(run_cell(
                backend,
                FetchMode::AfterMerge,
                ROUTE_SHARDS,
                queries,
                None,
                "threads",
                Some(spec),
            )?);
        }
    }
    Ok(cells)
}

/// Render the matrix as the repo's standard ASCII/CSV table.
pub fn table(cells: &[SmokeCell]) -> Table {
    let mut t = Table::new(
        "bench-smoke: serve scenario matrix — stage-2 reads/query (submitted \
         and post-tier device), stage-1 legs/query, and end-to-end latency \
         per {backend, fetch, shards[, tier][, route], seam} cell",
        &[
            "backend",
            "fetch",
            "shards",
            "tier",
            "route",
            "serve",
            "queries",
            "reads_per_query",
            "dev_reads_per_query",
            "s1_legs_per_query",
            "tier_hit_rate",
            "probe_recall",
            "p50_us",
            "p99_us",
            "merge_share",
        ],
    );
    for c in cells {
        t.row(vec![
            c.backend.to_string(),
            c.fetch.name().to_string(),
            format!("{}", c.shards),
            c.tier.clone().unwrap_or_else(|| "-".into()),
            c.route.clone().unwrap_or_else(|| "-".into()),
            c.serve.to_string(),
            format!("{}", c.queries),
            format!("{:.1}", c.reads_per_query),
            format!("{:.1}", c.device_reads_per_query),
            format!("{:.2}", c.stage1_legs_per_query),
            if c.tier.is_some() { format!("{:.2}", c.tier_hit_rate) } else { "-".into() },
            if c.route.is_some() { format!("{:.2}", c.probe_recall) } else { "-".into() },
            format!("{:.1}", c.p50_us),
            format!("{:.1}", c.p99_us),
            format!("{:.2}", c.merge_share),
        ]);
    }
    t
}

/// Serialize the matrix to the bench_smoke.json artifact shape.
pub fn to_json(cells: &[SmokeCell]) -> Json {
    let arr: Vec<Json> = cells
        .iter()
        .map(|c| {
            let mut fields = vec![
                ("backend", Json::Str(c.backend.to_string())),
                ("fetch", Json::Str(c.fetch.name().to_string())),
                ("shards", Json::Num(c.shards as f64)),
                ("serve", Json::Str(c.serve.to_string())),
                ("queries", Json::Num(c.queries as f64)),
                ("reads_per_query", Json::Num(c.reads_per_query)),
                ("device_reads_per_query", Json::Num(c.device_reads_per_query)),
                ("p50_us", Json::Num(c.p50_us)),
                ("p99_us", Json::Num(c.p99_us)),
                ("merge_share", Json::Num(c.merge_share)),
                ("stage1_legs_per_query", Json::Num(c.stage1_legs_per_query)),
            ];
            if let Some(t) = &c.tier {
                fields.push(("tier", Json::Str(t.clone())));
                fields.push(("tier_hits", Json::Num(c.tier_hits as f64)));
                fields.push(("tier_hit_rate", Json::Num(c.tier_hit_rate)));
            }
            if let Some(r) = &c.route {
                fields.push(("route", Json::Str(r.clone())));
                fields.push(("probes", Json::Num(c.probes as f64)));
                fields.push(("escalations", Json::Num(c.escalations as f64)));
                fields.push(("probe_recall", Json::Num(c.probe_recall)));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::Str(SCHEMA.to_string())),
        ("cells", Json::Arr(arr)),
    ])
}

/// Serialize the compact perf-trajectory document: one entry per cell
/// with just the numbers future PRs diff — stage-2 reads/query, stage-1
/// legs/query, and the p99. `make smoke` writes this as
/// `BENCH_SMOKE.json` at the repo root so the perf trajectory is a
/// first-class reviewed artifact, not a CI-only upload.
pub fn trajectory_json(cells: &[SmokeCell]) -> Json {
    let arr: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("cell", Json::Str(c.key())),
                ("reads_per_query", Json::Num(c.reads_per_query)),
                ("stage1_legs_per_query", Json::Num(c.stage1_legs_per_query)),
                ("p99_us", Json::Num(c.p99_us)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::Str(TRAJECTORY_SCHEMA.to_string())),
        ("cells", Json::Arr(arr)),
    ])
}

/// Write the perf-trajectory artifact (creating parent directories).
pub fn write_trajectory(path: &Path, cells: &[SmokeCell]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    std::fs::write(path, format!("{}\n", trajectory_json(cells)))
        .with_context(|| format!("writing {}", path.display()))
}

/// Write the artifact (creating parent directories).
pub fn write_artifact(path: &Path, cells: &[SmokeCell]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    std::fs::write(path, format!("{}\n", to_json(cells)))
        .with_context(|| format!("writing {}", path.display()))
}

/// Gate the measured matrix against a baseline document. Returns the list
/// of failures (empty = gate passes). `default_tol` applies when the
/// baseline carries no `tolerance` field.
pub fn gate(cells: &[SmokeCell], baseline: &Json, default_tol: f64) -> Vec<String> {
    let mut failures = Vec::new();
    let tol = baseline
        .get(&["tolerance"])
        .and_then(|t| t.as_f64())
        .unwrap_or(default_tol);
    let Some(base_cells) = baseline.get(&["cells"]).and_then(|c| c.as_obj()) else {
        return vec!["baseline has no 'cells' object".to_string()];
    };
    // static cells: compare against the checked-in expectation (reactor
    // cells are gated against their in-run threaded peer instead)
    for c in cells {
        if c.fetch == FetchMode::Adaptive
            || c.tier.is_some()
            || c.route.is_some()
            || c.serve == "reactor"
        {
            continue;
        }
        let key = c.key();
        let Some(base) = base_cells.get(&key) else {
            failures.push(format!("cell {key}: missing from baseline"));
            continue;
        };
        if let Some(want) = base.get(&["reads_per_query"]).and_then(|v| v.as_f64()) {
            if (c.reads_per_query - want).abs() > tol * want {
                failures.push(format!(
                    "cell {key}: reads_per_query {:.2} drifted >{:.0}% from baseline {want:.2}",
                    c.reads_per_query,
                    tol * 100.0
                ));
            }
        } else {
            failures.push(format!("cell {key}: baseline lacks reads_per_query"));
        }
        if let Some(budget) = base.get(&["p99_budget_us"]).and_then(|v| v.as_f64()) {
            if c.p99_us > budget {
                failures.push(format!(
                    "cell {key}: p99 {:.1}us over budget {budget:.1}us",
                    c.p99_us
                ));
            }
        }
    }
    // baseline cells the run never produced (a silently dropped scenario
    // must fail the gate, not shrink the matrix)
    for key in base_cells.keys() {
        if !cells.iter().any(|c| &c.key() == key) {
            failures.push(format!("cell {key}: in baseline but not measured"));
        }
    }
    // adaptive cells: bounded by the same run's static modes
    for c in cells {
        if c.fetch != FetchMode::Adaptive || c.tier.is_some() {
            continue;
        }
        let peer = |m: FetchMode| {
            cells.iter().find(|p| {
                p.backend == c.backend
                    && p.shards == c.shards
                    && p.fetch == m
                    && p.tier.is_none()
                    && p.route.is_none()
                    && p.serve == "threads"
            })
        };
        let (Some(spec), Some(merge)) =
            (peer(FetchMode::Speculative), peer(FetchMode::AfterMerge))
        else {
            failures.push(format!("cell {}: static peers missing from run", c.key()));
            continue;
        };
        let lo = merge.reads_per_query * (1.0 - tol);
        let hi = spec.reads_per_query * (1.0 + tol);
        if c.reads_per_query < lo || c.reads_per_query > hi {
            failures.push(format!(
                "cell {}: adaptive reads_per_query {:.2} outside [{lo:.2}, {hi:.2}] \
                 spanned by merge/spec peers",
                c.key(),
                c.reads_per_query
            ));
        }
    }
    // tier cells: gated relative to the same run's untiered peer — the
    // tier must never increase device traffic, and its submitted count
    // must match the peer's protocol cost (hit-rate absolutes are
    // reported, not gated; run_cell enforces the hits+device==submitted
    // identity before a cell ever reaches this gate)
    for c in cells {
        if c.tier.is_none() {
            continue;
        }
        let peer = cells.iter().find(|p| {
            p.backend == c.backend
                && p.shards == c.shards
                && p.fetch == c.fetch
                && p.tier.is_none()
                && p.route.is_none()
                && p.serve == "threads"
        });
        let Some(peer) = peer else {
            failures.push(format!("cell {}: untiered peer missing from run", c.key()));
            continue;
        };
        if (c.reads_per_query - peer.reads_per_query).abs() > tol * peer.reads_per_query {
            failures.push(format!(
                "cell {}: submitted reads/query {:.2} diverge from untiered peer {:.2} — \
                 the tier must not change what the router submits",
                c.key(),
                c.reads_per_query,
                peer.reads_per_query
            ));
        }
        if c.device_reads_per_query > peer.reads_per_query * (1.0 + tol) {
            failures.push(format!(
                "cell {}: tiered device reads/query {:.2} exceed untiered peer {:.2}",
                c.key(),
                c.device_reads_per_query,
                peer.reads_per_query
            ));
        }
        if c.device_reads_per_query <= 0.0 {
            failures.push(format!(
                "cell {}: zero device reads — the tier cannot absorb cold misses",
                c.key()
            ));
        }
    }
    // reactor cells: gated relative to the same run's threaded peer. The
    // two seams share the merge/promote/rank helpers, so for a static
    // fetch mode the submitted reads per query must match the threaded
    // cell (both are equivalence-pinned); adaptive reactor cells were
    // already bounded by the threaded static peers above.
    for c in cells {
        if c.serve != "reactor" || c.tier.is_some() || c.route.is_some() {
            continue;
        }
        let peer = cells.iter().find(|p| {
            p.backend == c.backend
                && p.shards == c.shards
                && p.fetch == c.fetch
                && p.tier.is_none()
                && p.route.is_none()
                && p.serve == "threads"
        });
        let Some(peer) = peer else {
            failures.push(format!("cell {}: threaded peer missing from run", c.key()));
            continue;
        };
        if c.fetch != FetchMode::Adaptive
            && (c.reads_per_query - peer.reads_per_query).abs() > tol * peer.reads_per_query
        {
            failures.push(format!(
                "cell {}: reactor reads/query {:.2} diverge from threaded peer {:.2} — \
                 the serving seam must not change the fetch protocol",
                c.key(),
                c.reads_per_query,
                peer.reads_per_query
            ));
        }
    }
    // route cells: the topm cell is gated against the same run's
    // route=all peer — stage-1 legs/query must stay under M plus the
    // deterministic probe quota and a bounded escalation allowance, its
    // p99 must not regress past the full-fan-out peer (with headroom),
    // and the probe-measured live recall must clear the floor. The
    // route=all cell itself must report *exactly* N legs/query: it is
    // the affinity code path with the cut disabled, so any drift there
    // is a routing accounting bug, not noise.
    for c in cells {
        let Some(label) = &c.route else { continue };
        if label == "all" {
            if (c.stage1_legs_per_query - c.shards as f64).abs() > 1e-6 {
                failures.push(format!(
                    "cell {}: route=all legs/query {:.2} != shard count {} — \
                     routing accounting drifted",
                    c.key(),
                    c.stage1_legs_per_query,
                    c.shards
                ));
            }
            continue;
        }
        let Some(m) = label.strip_prefix("topm:").and_then(|m| m.parse::<f64>().ok()) else {
            failures.push(format!("cell {}: unparseable route label '{label}'", c.key()));
            continue;
        };
        let q = c.queries.max(1) as f64;
        // probe quota: every probe_every-th query fans out to all N, so
        // the skipped (N−M) shards each cost ceil(q/probe_every) extra
        // legs across the run; escalations may add up to the allowance.
        let extra_per_skipped =
            ((q / ROUTE_PROBE_EVERY as f64).ceil() + ROUTE_ESC_ALLOWANCE * q) / q;
        let bound = m + (c.shards as f64 - m) * extra_per_skipped;
        if c.stage1_legs_per_query > bound {
            failures.push(format!(
                "cell {}: legs/query {:.2} over the selective bound {bound:.2} \
                 (M={m} + probe/escalation quota) — the fan-out cut is not happening",
                c.key(),
                c.stage1_legs_per_query
            ));
        }
        if c.probe_recall < ROUTE_RECALL_FLOOR {
            failures.push(format!(
                "cell {}: probe-measured recall {:.3} under floor {ROUTE_RECALL_FLOOR}",
                c.key(),
                c.probe_recall
            ));
        }
        let peer = cells.iter().find(|p| {
            p.backend == c.backend
                && p.shards == c.shards
                && p.fetch == c.fetch
                && p.serve == c.serve
                && p.route.as_deref() == Some("all")
        });
        let Some(peer) = peer else {
            failures.push(format!("cell {}: route=all peer missing from run", c.key()));
            continue;
        };
        if c.p99_us > peer.p99_us * (1.0 + ROUTE_P99_HEADROOM) {
            failures.push(format!(
                "cell {}: p99 {:.1}us worse than full-fan-out peer {:.1}us \
                 (+{:.0}% headroom) — routing must not cost tail latency",
                c.key(),
                c.p99_us,
                peer.p99_us,
                ROUTE_P99_HEADROOM * 100.0
            ));
        }
    }
    // tier / reactor / route scenarios the baseline pins but the run
    // never produced (a silently dropped scenario must fail the gate)
    for pin in ["tier_cells", "reactor_cells", "route_cells"] {
        if let Some(list) = baseline.get(&[pin]).and_then(|t| t.as_arr()) {
            for want in list {
                let Some(key) = want.as_str() else { continue };
                if !cells.iter().any(|c| c.key() == key) {
                    failures.push(format!("cell {key}: in baseline {pin} but not measured"));
                }
            }
        }
    }
    failures
}

/// Load and schema-check a baseline file.
pub fn load_baseline(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading baseline {}", path.display()))?;
    let doc = Json::parse(&text).map_err(|e| anyhow!("baseline {}: {e}", path.display()))?;
    let schema = doc.get(&["schema"]).and_then(|s| s.as_str()).unwrap_or("");
    anyhow::ensure!(
        schema == SCHEMA,
        "baseline schema '{schema}' != expected '{SCHEMA}'"
    );
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(
        backend: &'static str,
        fetch: FetchMode,
        shards: usize,
        rpq: f64,
        p99: f64,
    ) -> SmokeCell {
        SmokeCell {
            backend,
            fetch,
            shards,
            tier: None,
            serve: "threads",
            route: None,
            queries: 8,
            reads_per_query: rpq,
            device_reads_per_query: rpq,
            tier_hits: 0,
            tier_hit_rate: 0.0,
            p50_us: p99 / 2.0,
            p99_us: p99,
            merge_share: if fetch == FetchMode::Adaptive { 0.5 } else { 0.0 },
            stage1_legs_per_query: shards as f64,
            probes: 0,
            escalations: 0,
            probe_recall: 1.0,
        }
    }

    fn tier_cell(
        backend: &'static str,
        label: &str,
        submitted_rpq: f64,
        device_rpq: f64,
    ) -> SmokeCell {
        let hits = ((submitted_rpq - device_rpq) * 8.0) as u64;
        SmokeCell {
            backend,
            fetch: FetchMode::Speculative,
            shards: 2,
            tier: Some(label.to_string()),
            serve: "threads",
            route: None,
            queries: 8,
            reads_per_query: submitted_rpq,
            device_reads_per_query: device_rpq,
            tier_hits: hits,
            tier_hit_rate: 1.0 - device_rpq / submitted_rpq.max(1e-9),
            p50_us: 100.0,
            p99_us: 200.0,
            merge_share: 0.0,
            stage1_legs_per_query: 2.0,
            probes: 0,
            escalations: 0,
            probe_recall: 1.0,
        }
    }

    fn baseline(pairs: &[(&str, f64)]) -> Json {
        let cells: Vec<(&str, Json)> = pairs
            .iter()
            .map(|(k, v)| (*k, Json::obj(vec![("reads_per_query", Json::Num(*v))])))
            .collect();
        Json::obj(vec![
            ("schema", Json::Str(SCHEMA.to_string())),
            ("tolerance", Json::Num(0.25)),
            ("cells", Json::obj(cells)),
        ])
    }

    fn matched_run() -> Vec<SmokeCell> {
        vec![
            cell("mem", FetchMode::Speculative, 2, 128.0, 900.0),
            cell("mem", FetchMode::AfterMerge, 2, 64.0, 1800.0),
            cell("mem", FetchMode::Adaptive, 2, 100.0, 1000.0),
        ]
    }

    #[test]
    fn gate_passes_a_matched_run() {
        let b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        let failures = gate(&matched_run(), &b, 0.25);
        assert!(failures.is_empty(), "unexpected failures: {failures:?}");
    }

    #[test]
    fn gate_catches_read_regressions_beyond_tolerance() {
        let mut run = matched_run();
        run[1].reads_per_query = 100.0; // merge no longer cuts reads
        let b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("mem/merge/2"));
        // within tolerance passes
        run[1].reads_per_query = 70.0;
        assert!(gate(&run, &b, 0.25).is_empty());
    }

    #[test]
    fn gate_bounds_adaptive_by_its_static_peers() {
        let mut run = matched_run();
        run[2].reads_per_query = 200.0; // above spec * 1.25
        let b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("adaptive"));
        run[2].reads_per_query = 40.0; // below merge * 0.75
        assert_eq!(gate(&run, &b, 0.25).len(), 1);
    }

    #[test]
    fn gate_flags_missing_and_extra_cells() {
        let b = baseline(&[
            ("mem/spec/2", 128.0),
            ("mem/merge/2", 64.0),
            ("sim/spec/2", 128.0), // never measured
        ]);
        let run = matched_run();
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("sim/spec/2"));
        // and a measured static cell absent from the baseline fails too
        let b = baseline(&[("mem/spec/2", 128.0)]);
        let failures = gate(&run, &b, 0.25);
        assert!(failures.iter().any(|f| f.contains("mem/merge/2")));
    }

    #[test]
    fn gate_enforces_opt_in_latency_budgets() {
        let b = Json::obj(vec![
            ("schema", Json::Str(SCHEMA.to_string())),
            ("tolerance", Json::Num(0.25)),
            (
                "cells",
                Json::obj(vec![
                    (
                        "mem/spec/2",
                        Json::obj(vec![
                            ("reads_per_query", Json::Num(128.0)),
                            ("p99_budget_us", Json::Num(100.0)),
                        ]),
                    ),
                    ("mem/merge/2", Json::obj(vec![("reads_per_query", Json::Num(64.0))])),
                ]),
            ),
        ]);
        let failures = gate(&matched_run(), &b, 0.25); // p99 900us > 100us
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("over budget"));
    }

    #[test]
    fn gate_bounds_tier_cells_by_their_untiered_peer() {
        let mut run = matched_run();
        run.push(tier_cell("mem", "dram2:clock", 128.0, 80.0));
        let b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        assert!(gate(&run, &b, 0.25).is_empty(), "tier under its peer passes");
        // a tier that somehow inflates device reads beyond the peer fails
        run.last_mut().unwrap().device_reads_per_query = 200.0;
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("exceed untiered peer"));
        // a tier with zero device reads is an accounting impossibility
        run.last_mut().unwrap().device_reads_per_query = 0.0;
        let failures = gate(&run, &b, 0.25);
        assert!(failures.iter().any(|f| f.contains("zero device reads")), "{failures:?}");
        // the tier must not change what the router submits
        run.last_mut().unwrap().device_reads_per_query = 80.0;
        run.last_mut().unwrap().reads_per_query = 64.0; // != peer's 128
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("diverge from untiered peer"));
        // a tier cell with no untiered peer in the run fails
        let orphan = vec![tier_cell("sim", "dram2:clock", 128.0, 80.0)];
        let failures = gate(&orphan, &baseline(&[]), 0.25);
        assert!(failures.iter().any(|f| f.contains("untiered peer missing")), "{failures:?}");
    }

    fn reactor_cell(fetch: FetchMode, rpq: f64) -> SmokeCell {
        SmokeCell { serve: "reactor", ..cell("mem", fetch, 2, rpq, 500.0) }
    }

    #[test]
    fn gate_pins_reactor_cells_to_their_threaded_peer() {
        let b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        let mut run = matched_run();
        run.push(reactor_cell(FetchMode::AfterMerge, 64.0));
        assert!(gate(&run, &b, 0.25).is_empty(), "matching reactor cell passes");
        // the reactor seam must not change the protocol's read cost
        run.last_mut().unwrap().reads_per_query = 128.0;
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("mem/merge/2/reactor"), "{failures:?}");
        assert!(failures[0].contains("serving seam"), "{failures:?}");
        // a reactor cell with no threaded peer in the run fails
        let orphan =
            vec![cell("mem", FetchMode::Speculative, 2, 128.0, 900.0) /* no merge peer */, {
                SmokeCell { serve: "reactor", ..cell("sim", FetchMode::AfterMerge, 2, 64.0, 500.0) }
            }];
        let failures = gate(&orphan, &baseline(&[("mem/spec/2", 128.0)]), 0.25);
        assert!(failures.iter().any(|f| f.contains("threaded peer missing")), "{failures:?}");
    }

    #[test]
    fn gate_bounds_adaptive_reactor_cells_by_threaded_static_peers() {
        let b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        let mut run = matched_run();
        run.push(reactor_cell(FetchMode::Adaptive, 100.0));
        assert!(gate(&run, &b, 0.25).is_empty(), "in-band adaptive reactor passes");
        run.last_mut().unwrap().reads_per_query = 200.0; // above spec * 1.25
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("mem/adaptive/2/reactor"), "{failures:?}");
    }

    #[test]
    fn gate_flags_reactor_cells_pinned_but_not_measured() {
        let mut b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        if let Json::Obj(fields) = &mut b {
            fields.insert(
                "reactor_cells".into(),
                Json::Arr(vec![Json::Str("mem/merge/2/reactor".into())]),
            );
        }
        let failures = gate(&matched_run(), &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("reactor_cells"), "{failures:?}");
        let mut run = matched_run();
        run.push(reactor_cell(FetchMode::AfterMerge, 64.0));
        assert!(gate(&run, &b, 0.25).is_empty());
    }

    #[test]
    fn gate_flags_tier_cells_pinned_but_not_measured() {
        let b = Json::obj(vec![
            ("schema", Json::Str(SCHEMA.to_string())),
            ("tolerance", Json::Num(0.25)),
            (
                "cells",
                Json::obj(vec![
                    ("mem/spec/2", Json::obj(vec![("reads_per_query", Json::Num(128.0))])),
                    ("mem/merge/2", Json::obj(vec![("reads_per_query", Json::Num(64.0))])),
                ]),
            ),
            (
                "tier_cells",
                Json::Arr(vec![
                    Json::Str("mem/spec/2/dram2:clock".into()),
                    Json::Str("mem/spec/2/dram8:clock".into()),
                ]),
            ),
        ]);
        let mut run = matched_run();
        run.push(tier_cell("mem", "dram2:clock", 128.0, 80.0));
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("dram8:clock"));
        run.push(tier_cell("mem", "dram8:clock", 128.0, 70.0));
        assert!(gate(&run, &b, 0.25).is_empty());
    }

    fn route_cell(label: &str, legs: f64, p99: f64) -> SmokeCell {
        SmokeCell {
            route: Some(label.to_string()),
            stage1_legs_per_query: legs,
            probes: 2,
            escalations: 2,
            probe_recall: 0.97,
            queries: 48,
            ..cell("mem", FetchMode::AfterMerge, 4, 64.0, p99)
        }
    }

    #[test]
    fn gate_passes_route_cells_under_the_selective_bound() {
        let mut run = matched_run();
        run.push(route_cell("all", 4.0, 500.0));
        // bound at M=2, 4 shards, 48 queries: 2 + 2*((2 + 12)/48) ≈ 2.58
        run.push(route_cell("topm:2", 2.25, 400.0));
        let b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        let failures = gate(&run, &b, 0.25);
        assert!(failures.is_empty(), "unexpected failures: {failures:?}");
    }

    #[test]
    fn gate_catches_a_route_cell_that_stopped_cutting_fanout() {
        let mut run = matched_run();
        run.push(route_cell("all", 4.0, 500.0));
        run.push(route_cell("topm:2", 3.8, 400.0)); // nearly full fan-out
        let b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("selective bound"), "{failures:?}");
    }

    #[test]
    fn gate_catches_route_p99_regressions_and_recall_floor() {
        let mut run = matched_run();
        run.push(route_cell("all", 4.0, 500.0));
        run.push(route_cell("topm:2", 2.25, 900.0)); // > 500 * 1.5
        let b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("full-fan-out peer"), "{failures:?}");
        run.last_mut().unwrap().p99_us = 400.0;
        run.last_mut().unwrap().probe_recall = 0.5;
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("under floor"), "{failures:?}");
    }

    #[test]
    fn gate_requires_the_route_all_peer_and_exact_all_accounting() {
        let b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        // a topm cell with no route=all peer in the run fails
        let mut run = matched_run();
        run.push(route_cell("topm:2", 2.25, 400.0));
        let failures = gate(&run, &b, 0.25);
        assert!(
            failures.iter().any(|f| f.contains("route=all peer missing")),
            "{failures:?}"
        );
        // a route=all cell that doesn't report exactly N legs/query is an
        // accounting bug, not noise
        let mut run = matched_run();
        run.push(route_cell("all", 3.5, 500.0));
        run.push(route_cell("topm:2", 2.25, 400.0));
        let failures = gate(&run, &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("accounting drifted"), "{failures:?}");
    }

    #[test]
    fn gate_flags_route_cells_pinned_but_not_measured() {
        let mut b = baseline(&[("mem/spec/2", 128.0), ("mem/merge/2", 64.0)]);
        if let Json::Obj(fields) = &mut b {
            fields.insert(
                "route_cells".into(),
                Json::Arr(vec![Json::Str("mem/merge/4/route=topm:2".into())]),
            );
        }
        let failures = gate(&matched_run(), &b, 0.25);
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("route_cells"), "{failures:?}");
        let mut run = matched_run();
        run.push(route_cell("all", 4.0, 500.0));
        run.push(route_cell("topm:2", 2.25, 400.0));
        assert!(gate(&run, &b, 0.25).is_empty());
    }

    #[test]
    fn trajectory_json_round_trips() {
        let mut run = matched_run();
        run.push(route_cell("all", 4.0, 500.0));
        run.push(route_cell("topm:2", 2.25, 400.0));
        let doc = trajectory_json(&run);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get(&["schema"]).unwrap().as_str(), Some(TRAJECTORY_SCHEMA));
        let cells = parsed.get(&["cells"]).unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 5);
        assert_eq!(
            cells[4].get(&["cell"]).and_then(|v| v.as_str()),
            Some("mem/merge/4/route=topm:2")
        );
        assert_eq!(
            cells[4].get(&["stage1_legs_per_query"]).and_then(|v| v.as_f64()),
            Some(2.25)
        );
        assert_eq!(cells[4].get(&["p99_us"]).and_then(|v| v.as_f64()), Some(400.0));
    }

    #[test]
    fn artifact_json_round_trips() {
        let mut run = matched_run();
        run.push(tier_cell("mem", "dram2:clock", 128.0, 80.0));
        let doc = to_json(&run);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get(&["schema"]).unwrap().as_str(), Some(SCHEMA));
        let cells = parsed.get(&["cells"]).unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(
            cells[0].get(&["reads_per_query"]).and_then(|v| v.as_f64()),
            Some(128.0)
        );
        assert_eq!(cells[2].get(&["fetch"]).and_then(|v| v.as_str()), Some("adaptive"));
        assert_eq!(cells[0].get(&["serve"]).and_then(|v| v.as_str()), Some("threads"));
        assert_eq!(cells[3].get(&["tier"]).and_then(|v| v.as_str()), Some("dram2:clock"));
        assert_eq!(
            cells[3].get(&["device_reads_per_query"]).and_then(|v| v.as_f64()),
            Some(80.0)
        );
        assert!(cells[0].get(&["tier"]).is_none(), "untiered cells omit the tier field");
    }

    #[test]
    fn checked_in_baseline_parses_and_covers_the_static_matrix() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("rust/benches/common/smoke_baseline.json");
        let doc = load_baseline(&path).expect("baseline loads");
        let cells = doc.get(&["cells"]).unwrap().as_obj().unwrap();
        for backend in ["mem", "sim"] {
            for fetch in ["spec", "merge"] {
                for shards in [1, 2] {
                    let key = format!("{backend}/{fetch}/{shards}");
                    let c = cells.get(&key).unwrap_or_else(|| panic!("missing {key}"));
                    let rpq = c.get(&["reads_per_query"]).and_then(|v| v.as_f64()).unwrap();
                    // the equivalence-pinned expectations: N*k spec, k merge
                    let k = crate::runtime::SERVE.topk as f64;
                    let want = if fetch == "spec" { shards as f64 * k } else { k };
                    assert_eq!(rpq, want, "{key}");
                }
            }
        }
        // the tier scenario set is pinned too: exactly what run_matrix runs
        let tier_keys = doc.get(&["tier_cells"]).and_then(|t| t.as_arr()).expect("tier_cells");
        let mut want = Vec::new();
        for backend in ["mem", "sim"] {
            for mb in [2u64, 8] {
                for rule in ["clock", "breakeven"] {
                    want.push(format!("{backend}/spec/1/dram{mb}:{rule}"));
                }
            }
        }
        let got: Vec<&str> = tier_keys.iter().filter_map(|k| k.as_str()).collect();
        for w in &want {
            assert!(got.contains(&w.as_str()), "baseline tier_cells missing {w}");
        }
        assert_eq!(got.len(), want.len(), "unexpected extra tier cells pinned");
        // and the reactor scenario set: exactly what run_matrix runs
        let reactor_keys =
            doc.get(&["reactor_cells"]).and_then(|t| t.as_arr()).expect("reactor_cells");
        let mut want = Vec::new();
        for backend in ["mem", "sim"] {
            for fetch in ["spec", "merge", "adaptive"] {
                want.push(format!("{backend}/{fetch}/2/reactor"));
            }
        }
        let got: Vec<&str> = reactor_keys.iter().filter_map(|k| k.as_str()).collect();
        for w in &want {
            assert!(got.contains(&w.as_str()), "baseline reactor_cells missing {w}");
        }
        assert_eq!(got.len(), want.len(), "unexpected extra reactor cells pinned");
        // and the route scenario set: exactly what run_matrix runs
        let route_keys = doc.get(&["route_cells"]).and_then(|t| t.as_arr()).expect("route_cells");
        let mut want = Vec::new();
        for backend in ["mem", "sim"] {
            for spec in ["all", "topm:2"] {
                want.push(format!("{backend}/merge/{ROUTE_SHARDS}/route={spec}"));
            }
        }
        let got: Vec<&str> = route_keys.iter().filter_map(|k| k.as_str()).collect();
        for w in &want {
            assert!(got.contains(&w.as_str()), "baseline route_cells missing {w}");
        }
        assert_eq!(got.len(), want.len(), "unexpected extra route cells pinned");
    }
}
