//! Bench E13: regenerate Fig 12 (sharded multi-device scaling — read
//! tail and aggregate IOPS vs shard count at matched per-device config).
mod common;
use fivemin::figures::fig_shards;

fn main() {
    common::bench_figure("fig12", 3, || fig_shards::fig12(false));
}
