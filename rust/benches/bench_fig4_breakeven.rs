//! Bench E3: regenerate Fig 4 (break-even interval decompositions).
mod common;
use fivemin::figures::fig_breakeven;

fn main() {
    common::bench_figure("fig4", 20, || fig_breakeven::fig4().0);
    println!("{}", fig_breakeven::fig4().1);
}
