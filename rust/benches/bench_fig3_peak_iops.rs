//! Bench E1: regenerate Fig 3 (peak IOPS by NAND type x block size).
mod common;
use fivemin::figures::fig_peak_iops;

fn main() {
    common::bench_figure("fig3", 20, fig_peak_iops::fig3);
}
