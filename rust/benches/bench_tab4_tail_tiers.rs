//! Bench E4: regenerate Table IV (p99 tail-latency tiers vs rho_max).
mod common;
use fivemin::figures::fig_breakeven;

fn main() {
    common::bench_figure("tab4", 20, fig_breakeven::tab4);
}
