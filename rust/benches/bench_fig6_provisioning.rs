//! Bench E7: regenerate Fig 6 (min DRAM for viability/optimality).
mod common;
use fivemin::figures::fig_provisioning;

fn main() {
    common::bench_figure("fig6", 10, fig_provisioning::fig6);
}
