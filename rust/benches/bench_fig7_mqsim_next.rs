//! Bench E8-E11: regenerate Fig 7 (MQSim-Next validation + sensitivity).
//! Pass FIVEMIN_FULL=1 for the longer simulation windows.
mod common;
use fivemin::figures::fig_mqsim;

fn main() {
    let quick = std::env::var("FIVEMIN_FULL").is_err();
    common::bench_figure("fig7a", 1, || fig_mqsim::fig7a(quick));
    common::bench_figure("fig7b", 1, || fig_mqsim::fig7b(quick));
    common::bench_figure("fig7c", 1, || fig_mqsim::fig7c(quick));
    common::bench_figure("fig7d", 1, || fig_mqsim::fig7d(quick));
}
