//! Bench E13: regenerate Fig 10 (two-stage ANN throughput).
mod common;
use fivemin::figures::fig_casestudies;

fn main() {
    common::bench_figure("fig10", 5, fig_casestudies::fig10);
}
