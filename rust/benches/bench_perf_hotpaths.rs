//! Perf benches for the hot paths (EXPERIMENTS.md §Perf):
//!   * MQSim-Next event throughput (the simulator bottleneck)
//!   * analytical-framework evaluation rates (break-even, thresholds)
//!   * KV engine ops/s (in-process mechanism cost)
//!   * HNSW search latency
//!   * PJRT two-stage batch execution (when artifacts are present)

mod common;

use fivemin::config::{IoMix, NandKind, PlatformConfig, PlatformKind, SsdConfig};
use fivemin::kvstore::{CuckooParams, KvEngine, MemStore};
use fivemin::model::economics;
use fivemin::sim::{run_uniform, SimParams};
use fivemin::util::rng::{Rng, Zipf};
use fivemin::util::Timer;

fn bench_sim_event_rate() {
    use fivemin::sim::{SsdSim, TraceSource};
    use fivemin::workload::trace::{AddressDist, TraceCfg, TraceGen};
    let cfg = SsdConfig::storage_next(NandKind::Slc);
    let prm = SimParams::default_for(512);
    // split setup (precondition) from the event loop proper
    let t_setup = Timer::start();
    let mut sim = SsdSim::new(cfg.clone(), prm.clone());
    let setup = t_setup.elapsed_s();
    let mut gen = TraceGen::new(TraceCfg {
        n_blocks: sim.logical_blocks(),
        block_bytes: 512,
        read_frac: 0.9,
        addr: AddressDist::Uniform,
        seed: 1,
    });
    let mut src = TraceSource { gen: &mut gen };
    let t_run = Timer::start();
    let stats = sim.run_closed_loop(&mut src, 300_000, 3_000_000).clone();
    let wall = t_run.elapsed_s();
    let ios = stats.reads_done + stats.writes_done;
    println!(
        "bench sim_hotpath: setup {:.2}s | {:.2}M simulated IOPS | {:.0}k host IOs in {:.2}s wall -> {:.2}M IO/s sim rate",
        setup,
        stats.iops() / 1e6,
        ios as f64 / 1e3,
        wall,
        ios as f64 / wall / 1e6
    );
}

fn bench_breakeven_rate() {
    let plat = PlatformConfig::preset(PlatformKind::GpuGddr);
    let cfg = SsdConfig::storage_next(NandKind::Slc);
    let mix = IoMix::paper_default();
    let n = 1_000_000u64;
    let t = Timer::start();
    let mut acc = 0.0f64;
    for i in 0..n {
        let l = 512 << (i % 4);
        acc += economics::break_even(&plat, &cfg, l, mix).total;
    }
    let dt = t.elapsed_s();
    println!(
        "bench breakeven_eval: {:.1}M evals/s (acc {acc:.1})",
        n as f64 / dt / 1e6
    );
}

fn bench_kv_engine() {
    let n_items = 100_000u64;
    let params = CuckooParams::for_capacity(n_items, 0.7, 512, 64);
    let store = MemStore::new(params.n_buckets, params.slots_per_bucket);
    let mut engine = KvEngine::new(params, store, 512);
    for k in 1..=n_items {
        engine.put(k, k);
    }
    engine.flush();
    let zipf = Zipf::new(n_items as usize, 1.1);
    let mut rng = Rng::new(3);
    let ops = 400_000u64;
    let t = Timer::start();
    for i in 0..ops {
        let key = 1 + zipf.sample(&mut rng) as u64;
        if rng.bool(0.9) {
            std::hint::black_box(engine.get(key));
        } else {
            engine.put(key, i);
        }
    }
    let dt = t.elapsed_s();
    println!(
        "bench kv_engine: {:.2}M ops/s ({:.3} SSD IO/op)",
        ops as f64 / dt / 1e6,
        engine.ios_per_op()
    );
}

fn bench_hnsw_search() {
    use fivemin::ann::Hnsw;
    let mut rng = Rng::new(5);
    let d = 64;
    let mut idx = Hnsw::new(d, 12, 96, 6);
    for _ in 0..20_000 {
        let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        idx.insert(v);
    }
    let queries: Vec<Vec<f32>> = (0..200)
        .map(|_| (0..d).map(|_| rng.gaussian() as f32).collect())
        .collect();
    let t = Timer::start();
    let mut visited = 0u64;
    for q in &queries {
        let (_, c) = idx.search(q, 10, 96);
        visited += c.visited;
    }
    let dt = t.elapsed_s();
    println!(
        "bench hnsw_search: {:.0} QPS over 20k nodes ({:.0} visits/query)",
        queries.len() as f64 / dt,
        visited as f64 / queries.len() as f64
    );
}

fn bench_serving_two_stage() {
    use fivemin::coordinator::batcher::BatchPolicy;
    use fivemin::coordinator::{Coordinator, ServingCorpus};
    use fivemin::storage::BackendSpec;
    use std::sync::Arc;
    let dir = fivemin::runtime::default_artifacts_dir();
    let corpus = Arc::new(ServingCorpus::synthetic(1, 42));
    let co =
        Coordinator::start(dir, corpus.clone(), BatchPolicy::default(), BackendSpec::Mem)
            .unwrap();
    let mut rng = Rng::new(7);
    let n = 128;
    let t = Timer::start();
    let rxs: Vec<_> = (0..n)
        .map(|_| co.submit(corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng)))
        .collect();
    for r in rxs {
        r.recv().unwrap().unwrap();
    }
    let dt = t.elapsed_s();
    let st = co.stats();
    println!(
        "bench serving_two_stage: {:.0} QPS ({} batches, stage1 p50 {:.1}ms, stage2 p50 {:.1}ms)",
        n as f64 / dt,
        st.batches,
        st.stage1_ns.percentile(0.5) / 1e6,
        st.stage2_ns.percentile(0.5) / 1e6
    );
}

fn main() {
    bench_breakeven_rate();
    bench_sim_event_rate();
    bench_kv_engine();
    bench_hnsw_search();
    bench_serving_two_stage();
}
