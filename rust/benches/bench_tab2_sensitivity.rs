//! Bench E2: regenerate Table II (sensitivity to N_CH / N_NAND / tau_CMD).
mod common;
use fivemin::figures::fig_peak_iops;

fn main() {
    common::bench_figure("tab2", 20, fig_peak_iops::tab2);
}
