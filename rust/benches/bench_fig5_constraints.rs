//! Bench E5/E6: regenerate Fig 5 (constraint-aware break-even under host
//! budgets and tail-latency tiers).
mod common;
use fivemin::figures::fig_breakeven;

fn main() {
    common::bench_figure("fig5ab", 20, fig_breakeven::fig5_host_budget);
    common::bench_figure("fig5cd", 20, fig_breakeven::fig5_latency_tiers);
}
