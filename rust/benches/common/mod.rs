//! Shared mini-bench harness (criterion is unavailable offline): timed
//! sections with mean/min reporting, plus the figure-regeneration wrapper
//! used by every per-figure bench target.

// Each bench binary compiles its own copy of this module and typically
// uses only one of the two helpers.
#![allow(dead_code)]

use std::path::Path;

use fivemin::util::table::Table;
use fivemin::util::{bench_time, Timer};

/// Time a closure and report; returns the closure's last result.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> T {
    let (mean, min) = bench_time(warmup, iters, &mut f);
    println!(
        "bench {name:<40} mean {:>10.3} ms   min {:>10.3} ms   ({iters} iters)",
        mean * 1e3,
        min * 1e3
    );
    f()
}

/// Regenerate one figure table, print it, persist the CSV, and report the
/// generation time — the contract of every `bench_figX` target.
pub fn bench_figure(id: &str, iters: usize, f: impl Fn() -> Table) {
    let t = Timer::start();
    let table = f();
    let first = t.elapsed_s();
    println!("{}", table.render());
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&out).ok();
    table.write_csv(&out.join(format!("{id}.csv"))).unwrap();
    if iters > 1 {
        let (mean, min) = bench_time(0, iters - 1, &f);
        println!(
            "bench {id:<40} first {:>8.1} ms   mean {:>8.1} ms   min {:>8.1} ms",
            first * 1e3,
            mean * 1e3,
            min * 1e3
        );
    } else {
        println!("bench {id:<40} took {:>8.1} ms", first * 1e3);
    }
}
