//! Bench E12: regenerate Fig 8 (KV-store achievable throughput), plus the
//! flush-path batching comparison on the simulator backend: consolidated
//! WAL groups committed as one submit/wait burst vs one device round-trip
//! per bucket access.
mod common;

use fivemin::figures::fig_casestudies;
use fivemin::kvstore::{BackedStore, CuckooParams, KvEngine, MemStore};
use fivemin::storage::BackendSpec;
use fivemin::util::stats::Samples;

/// Load the engine through WAL flushes on a small simulated device and
/// sample the device-time span each flush consumes. Returns (p50, p99)
/// flush span in microseconds.
fn flush_spans(batch_flush: bool) -> (f64, f64) {
    let n_items = 4_000u64;
    let p = CuckooParams::for_capacity(n_items, 0.7, 512, 64);
    let spec = BackendSpec::small_sim(512);
    let mut store = BackedStore::new(
        MemStore::new(p.n_buckets, p.slots_per_bucket),
        spec.build(),
    );
    store.batch_flush = batch_flush;
    // high threshold: flush points are controlled by this driver, not puts
    let mut e = KvEngine::new(p, store, 1_000_000);
    let mut spans = Samples::new();
    let mut last_ns = 0u64;
    for k in 1..=n_items {
        e.put(k, k.wrapping_mul(0x9E37_79B9));
        if k % 256 == 0 {
            e.flush();
            let now_ns = e.store.snapshot().stats.virtual_ns;
            spans.push((now_ns - last_ns) as f64 / 1e3);
            last_ns = now_ns;
        }
    }
    (spans.percentile(0.5), spans.percentile(0.99))
}

fn main() {
    common::bench_figure("fig8", 5, fig_casestudies::fig8);
    println!("{}", fig_casestudies::fig8_chart());

    println!("\nflush-path batching on the sim backend (device-time per 256-put flush):");
    let (p50_per, p99_per) = flush_spans(false);
    let (p50_batched, p99_batched) = flush_spans(true);
    println!("  per-bucket waits : p50 {p50_per:>9.1} us   p99 {p99_per:>9.1} us");
    println!("  batched groups   : p50 {p50_batched:>9.1} us   p99 {p99_batched:>9.1} us");
    println!(
        "  tail improvement : {:.2}x at p99 ({:.2}x at p50)",
        p99_per / p99_batched.max(1e-9),
        p50_per / p50_batched.max(1e-9),
    );
}
