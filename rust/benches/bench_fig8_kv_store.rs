//! Bench E12: regenerate Fig 8 (KV-store achievable throughput).
mod common;
use fivemin::figures::fig_casestudies;

fn main() {
    common::bench_figure("fig8", 5, fig_casestudies::fig8);
    println!("{}", fig_casestudies::fig8_chart());
}
