//! The reactor's bounded-memory contract, asserted rather than claimed:
//! 10k concurrent open-loop queries through one `partitioned_reactor`
//! router (a) all complete with correct answers, (b) never grow the
//! tracked pending set past the admission window — queries beyond it
//! wait in the inbox holding only their payload — and (c) never spawn a
//! per-query thread: the process thread count stays flat at the fixed
//! serving topology (workers + one reactor loop) while 10k queries are
//! in flight.

use std::sync::Arc;

use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{Coordinator, FetchMode, ReactorConfig, Router, ServingCorpus};
use fivemin::runtime::default_artifacts_dir;
use fivemin::storage::BackendSpec;
use fivemin::util::rng::Rng;

const N_QUERIES: usize = 10_000;
const ADMISSION: usize = 256;

fn start_reactor_router(corpus: &Arc<ServingCorpus>, shards: usize) -> Router {
    let workers = corpus
        .partitions(shards)
        .unwrap()
        .into_iter()
        .map(|part| {
            Coordinator::start(
                default_artifacts_dir(),
                Arc::new(part),
                BatchPolicy::default(),
                BackendSpec::Mem,
            )
        })
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    Router::partitioned_reactor(
        workers,
        FetchMode::AfterMerge,
        ReactorConfig { admission: ADMISSION, ..Default::default() },
    )
    .unwrap()
}

/// Threads in this process, from /proc/self/stat field 20 (`num_threads`
/// — field 2 is `comm`, which may contain spaces, so parse from the
/// closing paren). `None` where /proc isn't available; the caller
/// degrades to the pending-set assertion alone.
fn process_threads() -> Option<u64> {
    let stat = std::fs::read_to_string("/proc/self/stat").ok()?;
    let after = &stat[stat.rfind(')')? + 2..];
    after.split_whitespace().nth(17)?.parse().ok()
}

#[test]
fn ten_thousand_open_loop_queries_complete_within_the_admission_window() {
    let shards = 2usize;
    let corpus = Arc::new(ServingCorpus::synthetic(shards, 0xB0DE));
    let router = start_reactor_router(&corpus, shards);
    let mut rng = Rng::new(0x10_000);

    let threads_before = process_threads();
    // open loop: submit all 10k without waiting on any completion —
    // every submit returns immediately, so the full load is in flight
    // (inbox + tracked pending) at once
    let pending: Vec<(usize, _)> = (0..N_QUERIES)
        .map(|i| {
            let target = (i * 73) % corpus.n;
            (target, router.submit(corpus.query_near(target, 0.01, &mut rng)))
        })
        .collect();
    // sample the thread count while the load is in flight: a
    // thread-per-query design would show thousands here
    let threads_during = process_threads();

    let mut answered = 0usize;
    let mut hits = 0usize;
    for (target, rx) in pending {
        let r = rx.recv().expect("reactor dropped a query").expect("query failed");
        assert!(!r.ids.is_empty(), "empty answer");
        if r.ids[0] as usize == target {
            hits += 1;
        }
        answered += 1;
    }
    assert_eq!(answered, N_QUERIES, "every open-loop query must complete");
    // near-duplicate queries over a synthetic corpus: recall@1 should be
    // essentially perfect — a cheap guard that answers are real, not
    // placeholders drained under pressure
    assert!(hits * 10 >= answered * 9, "recall@1 collapsed: {hits}/{answered}");

    let rep = router.reactor_report().expect("reactor router reports metrics");
    assert_eq!(rep.completed, N_QUERIES as u64, "reactor counted every completion");
    assert_eq!(rep.admitted, N_QUERIES as u64, "reactor admitted every query");
    assert!(
        rep.peak_pending <= ADMISSION as u64,
        "peak tracked pending {} exceeded the admission window {ADMISSION}",
        rep.peak_pending
    );
    // under 10k concurrent queries the window must actually have been
    // exercised, not sized past the load
    assert!(rep.peak_pending > 0, "reactor never tracked a query");

    if let (Some(before), Some(during)) = (threads_before, threads_during) {
        // no thread-per-query: in-flight load must not grow the thread
        // count at all (the serving topology is fixed at startup). Allow
        // a tiny slack for unrelated runtime threads.
        assert!(
            during <= before + 4,
            "thread count grew from {before} to {during} under open-loop load — \
             looks like a thread per query"
        );
    }
}

#[test]
fn reactor_over_sim_workers_stays_within_the_admission_window() {
    // The bounded-memory contract re-run against the genuinely async
    // storage path: sim-backed partitions, so every stage-2 burst goes
    // through submit/sweep on a real discrete-event device while the
    // reactor keeps feeding the workers.
    let shards = 2usize;
    let admission = 64usize;
    let n = 256usize;
    let corpus = Arc::new(ServingCorpus::synthetic(shards, 0xB0E0));
    let workers = corpus
        .partitions(shards)
        .unwrap()
        .into_iter()
        .map(|part| {
            let spec = BackendSpec::small_sim(4096).for_capacity(part.n as u64);
            Coordinator::start(
                default_artifacts_dir(),
                Arc::new(part),
                BatchPolicy::default(),
                spec,
            )
        })
        .collect::<anyhow::Result<Vec<_>>>()
        .unwrap();
    let router = Router::partitioned_reactor(
        workers,
        FetchMode::AfterMerge,
        ReactorConfig { admission, ..Default::default() },
    )
    .unwrap();
    let mut rng = Rng::new(0xB0E1);
    let pending: Vec<_> = (0..n)
        .map(|i| router.submit(corpus.query_near((i * 37) % corpus.n, 0.01, &mut rng)))
        .collect();
    for rx in pending {
        rx.recv().expect("reactor dropped a query").expect("query failed");
    }
    let rep = router.reactor_report().unwrap();
    assert_eq!(rep.completed, n as u64, "every query completes on the async path");
    assert!(
        rep.peak_pending <= admission as u64,
        "peak tracked pending {} exceeded the admission window {admission}",
        rep.peak_pending
    );
    // after-merge over sim devices: exactly k stage-2 reads per query in
    // total, counted at completion time by the async sweep
    let st = router.settled_stats(std::time::Duration::from_secs(10));
    assert_eq!(
        st.ssd_reads,
        (n * fivemin::runtime::SERVE.topk) as u64,
        "async completion accounting must match the blocking path exactly"
    );
}

#[test]
fn admission_window_of_one_still_serves_correct_answers() {
    // Degenerate window: the reactor is allowed to track exactly one
    // query at a time, so the other 63 wait in the inbox. Everything
    // must still complete, in order, with bounded tracking.
    let corpus = Arc::new(ServingCorpus::synthetic(1, 0xB0DF));
    let workers = vec![Coordinator::start(
        default_artifacts_dir(),
        corpus.clone(),
        BatchPolicy::default(),
        BackendSpec::Mem,
    )
    .unwrap()];
    let router = Router::partitioned_reactor(
        workers,
        FetchMode::Speculative,
        ReactorConfig { admission: 1, ..Default::default() },
    )
    .unwrap();
    let mut rng = Rng::new(7);
    let pending: Vec<_> =
        (0..64).map(|i| router.submit(corpus.query_near(i % corpus.n, 0.01, &mut rng))).collect();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let rep = router.reactor_report().unwrap();
    assert_eq!(rep.completed, 64);
    assert_eq!(rep.peak_pending, 1, "window of one tracks exactly one query");
}
