//! Cross-module integration: the analytical pipeline end to end
//! (device model -> usable IOPS -> break-even -> viability -> advice),
//! plus sampled-vs-closed-form workload cross-validation.

use fivemin::config::{IoMix, NandKind, PlatformConfig, PlatformKind, SsdConfig};
use fivemin::model::{economics, platform, queueing, upgrade};
use fivemin::util::rng::Rng;
use fivemin::workload::LognormalProfile;

#[test]
fn pipeline_cpu_vs_gpu_headline() {
    // The full RQ1->RQ3 pipeline produces the paper's ordering everywhere.
    let mix = IoMix::paper_default();
    let ssd = SsdConfig::storage_next(NandKind::Slc);
    let cpu = PlatformConfig::preset(PlatformKind::CpuDdr);
    let gpu = PlatformConfig::preset(PlatformKind::GpuGddr);
    for &l in &fivemin::config::BLOCK_SIZES {
        let u_cpu = queueing::usable_iops(&ssd, &cpu, l, mix, queueing::LatencyTargets::none());
        let u_gpu = queueing::usable_iops(&ssd, &gpu, l, mix, queueing::LatencyTargets::none());
        assert!(u_gpu.usable >= u_cpu.usable);
        let cost = fivemin::model::ssd::ssd_cost(&ssd).total;
        let be_cpu = economics::break_even_with_iops(&cpu, cost, u_cpu.usable, l);
        let be_gpu = economics::break_even_with_iops(&gpu, cost, u_gpu.usable, l);
        assert!(
            be_gpu.total < be_cpu.total,
            "l={l}: GPU {:.1}s !< CPU {:.1}s",
            be_gpu.total,
            be_cpu.total
        );
        assert!(be_gpu.total < 10.0, "GPU always in the seconds regime");
    }
}

#[test]
fn advice_converges_to_optimal() {
    // Iteratively applying the advisor's recommendation reaches Keep.
    let mix = IoMix::paper_default();
    let ssd = SsdConfig::storage_next(NandKind::Slc);
    let plat = PlatformConfig::preset(PlatformKind::GpuGddr);
    let profile = LognormalProfile::calibrated(200e9, 1.2, 1e9, 512);
    let mut dram = 4e9; // start tiny
    for _round in 0..8 {
        let advice = upgrade::advise(&profile, &plat, &ssd, mix,
            queueing::LatencyTargets::none(), dram);
        match &advice.recommendations[0] {
            upgrade::Recommendation::Keep => {
                assert!(advice.verdict.viable && advice.verdict.economics_optimal);
                return;
            }
            upgrade::Recommendation::ResizeDramTo(b)
            | upgrade::Recommendation::IncreaseDramCapacity(b) => {
                dram = *b * 1.02; // apply with 2% headroom
            }
            upgrade::Recommendation::IncreaseSsdThroughput { .. } => {
                // at very small DRAM the uncached stream exceeds the SSD
                // array — the alternative fix is caching more: grow DRAM
                // to the framework's viable capacity.
                let pr = platform::provision(&profile, &plat, &ssd, mix,
                    queueing::LatencyTargets::none()).unwrap();
                dram = pr.cap_viable * 1.02;
            }
            other => panic!("unexpected advice on GPU+SN: {other:?}"),
        }
    }
    panic!("advisor failed to converge in 8 rounds");
}

#[test]
fn sampled_workload_agrees_with_assessment() {
    // assess() on the closed-form profile matches a brute-force check on a
    // sampled instance of the same workload.
    let profile = LognormalProfile::calibrated(50e9, 1.0, 1e7, 4096);
    let plat = PlatformConfig::preset(PlatformKind::GpuGddr);
    let ssd = SsdConfig::storage_next(NandKind::Slc);
    let mix = IoMix::paper_default();
    let dram = 8e9;
    let v = platform::assess(&profile, &plat, &ssd, mix,
        queueing::LatencyTargets::none(), dram);

    // brute force on 200k sampled intervals
    let mut rng = Rng::new(77);
    let n = 200_000usize;
    let mut taus = profile.sample(n, &mut rng);
    taus.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let scale = profile.n_blk / n as f64;
    // T_C: capacity quantile
    let k = ((dram / 4096.0) / scale) as usize;
    let t_c_sampled = taus[k.min(n - 1)];
    assert!(
        (t_c_sampled - v.t_c).abs() / v.t_c < 0.1,
        "T_C sampled {t_c_sampled} vs analytic {}",
        v.t_c
    );
    // uncached throughput at T_C
    let psi_d: f64 = taus.iter().filter(|&&t| t > t_c_sampled).map(|t| 1.0 / t).sum::<f64>()
        * scale * 4096.0;
    let analytic = profile.psi_uncached(v.t_c);
    assert!(
        (psi_d - analytic).abs() / analytic < 0.1,
        "Psi_d sampled {psi_d:.3e} vs analytic {analytic:.3e}"
    );
}

#[test]
fn normal_vs_storage_next_crossover_at_4kb() {
    // At 4KB the two device classes converge (same media block); below
    // 4KB Storage-Next wins increasingly — the Fig 3/4 crossover shape.
    let mix = IoMix::paper_default();
    let cpu = PlatformConfig::preset(PlatformKind::CpuDdr);
    let mut prev_ratio = f64::INFINITY;
    for &l in &[512u64, 1024, 2048, 4096] {
        let sn = economics::break_even(&cpu, &SsdConfig::storage_next(NandKind::Slc), l, mix);
        let mut nr_cfg = SsdConfig::normal(NandKind::Slc);
        nr_cfg.tau_cmd = 150e-9; // isolate the ECC effect
        let nr = economics::break_even(&cpu, &nr_cfg, l, mix);
        let ratio = nr.total / sn.total;
        assert!(ratio <= prev_ratio + 1e-9, "advantage should shrink with block size");
        prev_ratio = ratio;
    }
    assert!((prev_ratio - 1.0).abs() < 0.05, "at 4KB both classes coincide");
}
