//! Full-scale MQSim-Next validation against the analytic model (Fig 7a/7b
//! trends). Ignored by default in quick runs — the figure bench regenerates
//! the full sweep; this integration test pins the headline points.

use fivemin::config::{IoMix, NandKind, SsdConfig};
use fivemin::model::ssd;
use fivemin::sim::{run_uniform, SimParams};

#[test]
fn fig7a_sim_tracks_model_at_512b_and_4kb() {
    let cfg = SsdConfig::storage_next(NandKind::Slc);
    for (l_blk, lo, hi) in [(512u32, 50e6, 110e6), (4096, 9e6, 25e6)] {
        let prm = SimParams::default_for(l_blk);
        let s = run_uniform(&cfg, &prm, 0.9, 300, 1500);
        let model = ssd::ssd_peak_iops(&cfg, l_blk as u64, IoMix::paper_default()).effective;
        let iops = s.iops();
        // Fig 7a: simulator aligns with the model, slightly above it
        // (conservative Φ_WA in the model, SCA command/data overlap in sim).
        assert!(
            iops > lo && iops < hi,
            "l={l_blk}: sim {:.1}M outside [{:.0}M,{:.0}M] (model {:.1}M)",
            iops / 1e6, lo / 1e6, hi / 1e6, model / 1e6
        );
        assert!(
            iops > 0.8 * model,
            "l={l_blk}: sim {:.1}M below 0.8x model {:.1}M",
            iops / 1e6, model / 1e6
        );
    }
}

#[test]
fn fig7b_read_write_ratio_ordering() {
    let cfg = SsdConfig::storage_next(NandKind::Slc);
    let prm = SimParams::default_for(512);
    let mut prev = f64::INFINITY;
    // Fig 7b: 82M (read-only) > 68M (90:10) > 52M (70:30) > 34M (50:50)
    for rf in [1.0, 0.9, 0.7, 0.5] {
        let s = run_uniform(&cfg, &prm, rf, 300, 1200);
        let iops = s.iops();
        assert!(
            iops < prev * 1.02,
            "IOPS must fall as writes grow: rf={rf} {:.1}M prev {:.1}M",
            iops / 1e6, prev / 1e6
        );
        prev = iops;
    }
}
