//! Overload governance end to end: the shedding ladder's degraded
//! service levels, driven through a real partitioned router against real
//! workers, must stay *honest* — every degraded answer is a deterministic
//! function of the full answer (the promote-set prefix), never a
//! different candidate mix, and every query is accounted as accepted or
//! rejected.
//!
//! Ladder *dynamics* (trip thresholds, escalation order, dwell,
//! hysteresis, flap bounds) are unit-tested in
//! `rust/src/coordinator/overload.rs`; arrival-process statistics in
//! `rust/src/workload/arrival.rs`; this suite pins the serving-path
//! integration: rungs are forced and the answers compared bit for bit
//! against an ungoverned router serving identical queries.

use std::collections::VecDeque;
use std::sync::Arc;

use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{
    Coordinator, FetchMode, OverloadConfig, OverloadController, Router, Rung, ServingCorpus,
    SloConfig, TenantClass,
};
use fivemin::runtime::{default_artifacts_dir, SERVE};
use fivemin::storage::BackendSpec;
use fivemin::util::rng::Rng;
use fivemin::workload::{ArrivalConfig, ArrivalGen};

const SHARDS: usize = 2;
const QUERIES: usize = 24;

fn workers(corpus: &Arc<ServingCorpus>) -> Vec<Coordinator> {
    corpus
        .partitions(SHARDS)
        .unwrap()
        .into_iter()
        .map(|part| {
            Coordinator::start(
                default_artifacts_dir(),
                Arc::new(part),
                BatchPolicy::default(),
                BackendSpec::Mem,
            )
            .unwrap()
        })
        .collect()
}

/// Governance config that never moves on its own: latency budgets and
/// queue depth far out of reach, window too large to ever close. Tests
/// pin rungs with `force_rung` and observe pure service-level behavior.
fn inert_config(shrink_k: usize) -> OverloadConfig {
    let slo = SloConfig { p50_us: 1e12, p95_us: 1e12, p99_us: 1e12, max_queue_depth: 1 << 20 };
    OverloadConfig { window: 1 << 30, shrink_k, ..OverloadConfig::for_slo(slo) }
}

/// Identical query streams for the governed and ungoverned routers.
fn queries(corpus: &Arc<ServingCorpus>) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0x0_5ED);
    (0..QUERIES)
        .map(|_| corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng))
        .collect()
}

fn serve_full(corpus: &Arc<ServingCorpus>, qs: &[Vec<f32>]) -> Vec<(Vec<u32>, Vec<f32>, Vec<f32>)> {
    let router = Router::partitioned_with(workers(corpus), FetchMode::AfterMerge).unwrap();
    qs.iter()
        .map(|q| {
            let r = router.query(q.clone()).unwrap();
            (r.ids, r.scores, r.reduced)
        })
        .collect()
}

/// The promote-order prefix of a full answer: its (reduced, id) pairs
/// re-sorted the way the merger promotes (reduced desc, id asc — the
/// worker's exact tie order), truncated to `k`. This is the reference
/// every degraded answer must reproduce bit for bit.
fn promote_prefix(ids: &[u32], reduced: &[f32], k: usize) -> Vec<(f32, u32)> {
    let mut cand: Vec<(f32, u32)> =
        reduced.iter().copied().zip(ids.iter().copied()).collect();
    cand.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    cand.truncate(k);
    cand
}

#[test]
fn normal_rung_answers_match_the_ungoverned_router_bit_for_bit() {
    let corpus = Arc::new(ServingCorpus::synthetic(SHARDS, 0x0_5ED));
    let qs = queries(&corpus);
    let full = serve_full(&corpus, &qs);
    let router = Router::partitioned_overload(
        workers(&corpus),
        FetchMode::AfterMerge,
        inert_config((SERVE.topk / 2).max(1)),
        None,
    )
    .unwrap();
    assert_eq!(router.overload().unwrap().rung(), Rung::Normal);
    for (q, want) in qs.iter().zip(&full) {
        let rx = router.try_submit(q.clone()).expect("normal rung admits everything");
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.ids, want.0, "governed full service must not change the answer");
        assert_eq!(got.scores, want.1);
        assert_eq!(got.reduced, want.2);
    }
    let rep = router.overload_report().unwrap();
    assert_eq!(rep.admitted, QUERIES as u64);
    assert_eq!(rep.completed, QUERIES as u64, "every admission fed back a completion");
    assert_eq!(rep.rejected, 0);
    assert_eq!(rep.in_flight, 0, "gauge drains to zero once answers land");
    assert_eq!(rep.rung, Rung::Normal, "inert guardrails never move the ladder");
}

#[test]
fn stage1_only_degraded_answers_are_the_promote_prefix_with_no_device_reads() {
    let corpus = Arc::new(ServingCorpus::synthetic(SHARDS, 0x0_5ED));
    let qs = queries(&corpus);
    let full = serve_full(&corpus, &qs);
    let shrink_k = (SERVE.topk / 2).max(1);
    let router = Router::partitioned_overload(
        workers(&corpus),
        FetchMode::AfterMerge,
        inert_config(shrink_k),
        None,
    )
    .unwrap();
    router.overload().unwrap().force_rung(Rung::Stage1Only);
    for (q, want) in qs.iter().zip(&full) {
        let rx = router.try_submit(q.clone()).expect("stage1-only still admits");
        let got = rx.recv().unwrap().unwrap();
        // the equivalence arm: degraded == the merger's reduced top-k
        // prefix of the full answer, bit for bit
        let prefix = promote_prefix(&want.0, &want.2, shrink_k);
        assert_eq!(got.ids, prefix.iter().map(|c| c.1).collect::<Vec<_>>());
        assert_eq!(got.reduced, prefix.iter().map(|c| c.0).collect::<Vec<_>>());
        assert!(
            got.scores.is_empty(),
            "degraded answers must carry the honesty marker (no stage-2 scores)"
        );
        assert_eq!(got.ids.len(), shrink_k);
    }
    // stage-1-only service never touches stage 2: zero device reads
    let st = router.merged_stats();
    assert_eq!(st.ssd_reads, 0, "stage1-only must issue no stage-2 reads");
    assert_eq!(st.fetch_legs, 0, "no phase-2 fetch legs dispatched");
    let rep = router.overload_report().unwrap();
    assert_eq!(rep.completed, QUERIES as u64, "degraded completions feed the guardrails too");
}

#[test]
fn shrink_k_rung_serves_the_promote_prefix_with_full_scores() {
    let corpus = Arc::new(ServingCorpus::synthetic(SHARDS, 0x0_5ED));
    let qs = queries(&corpus);
    let full = serve_full(&corpus, &qs);
    let shrink_k = (SERVE.topk / 2).max(1);
    let router = Router::partitioned_overload(
        workers(&corpus),
        FetchMode::AfterMerge,
        inert_config(shrink_k),
        None,
    )
    .unwrap();
    router.overload().unwrap().force_rung(Rung::ShrinkK);
    for (q, want) in qs.iter().zip(&full) {
        let rx = router.try_submit(q.clone()).expect("shrink-k admits");
        let got = rx.recv().unwrap().unwrap();
        // shrink-k promotes the prefix, then stage 2 runs as usual: the
        // expected answer is the prefix re-ranked by the full scores the
        // ungoverned router measured for the same ids
        let prefix = promote_prefix(&want.0, &want.2, shrink_k);
        let score_of = |id: u32| {
            let i = want.0.iter().position(|&x| x == id).expect("prefix id is in full answer");
            want.1[i]
        };
        let mut expect: Vec<(f32, f32, u32)> =
            prefix.iter().map(|&(red, id)| (red, score_of(id), id)).collect();
        expect.sort_by(|a, b| b.1.total_cmp(&a.1));
        assert_eq!(got.ids, expect.iter().map(|c| c.2).collect::<Vec<_>>());
        assert_eq!(got.scores, expect.iter().map(|c| c.1).collect::<Vec<_>>());
        assert_eq!(got.reduced, expect.iter().map(|c| c.0).collect::<Vec<_>>());
        assert!(!got.scores.is_empty(), "shrink-k still re-ranks with stage-2 scores");
    }
    // k device reads per query shrink to shrink_k per query
    let st = router.settled_stats(std::time::Duration::from_secs(10));
    assert_eq!(
        st.ssd_reads,
        (QUERIES * shrink_k) as u64,
        "shrink-k cuts stage-2 reads to the shrunk promote set"
    );
}

#[test]
fn normal_rung_tenant_answers_match_the_ungoverned_router_per_tenant() {
    // Tenant-aware governance at Normal must be invisible in the
    // answers: whatever the per-tenant deficit state says, rung 0 serves
    // every tenant the full plan, bit-identical to an ungoverned router.
    let corpus = Arc::new(ServingCorpus::synthetic(SHARDS, 0x0_5ED));
    let qs = queries(&corpus);
    let full = serve_full(&corpus, &qs);
    let cfg = OverloadConfig {
        tenants: TenantClass::derive(4, 1.2),
        ..inert_config((SERVE.topk / 2).max(1))
    };
    let router =
        Router::partitioned_overload(workers(&corpus), FetchMode::AfterMerge, cfg, None).unwrap();
    for (i, (q, want)) in qs.iter().zip(&full).enumerate() {
        let tenant = (i % 4) as u32;
        let rx = router.try_submit_tenant(q.clone(), tenant).expect("normal rung admits");
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.ids, want.0, "tenant {tenant}: governed full service changed the answer");
        assert_eq!(got.scores, want.1);
        assert_eq!(got.reduced, want.2);
    }
    let rep = router.overload_report().unwrap();
    assert_eq!(rep.rung, Rung::Normal);
    assert_eq!(rep.admitted, QUERIES as u64);
    // per-tenant accounting saw every class, and completions drained
    for t in rep.tenants.iter().filter(|t| t.tenant != u32::MAX) {
        assert_eq!(t.admitted, (QUERIES / 4) as u64);
        assert_eq!(t.completed, t.admitted, "tenant completions feed back per class");
    }
}

/// Fairness-gate bounds, mirrored from the `"fairness"` block of the
/// sustained phase in `rust/benches/common/soak_baseline.json`: a cold
/// tenant's shed rate may not exceed `MAX_SHED_RATIO` × the hot
/// tenant's, plus `ABS_SLACK`. (Uniform shedding — everyone at the same
/// rate `s` — violates this whenever `s > ABS_SLACK / (1 −
/// MAX_SHED_RATIO)` = 40%, which a sustained 2× overload forces, so the
/// gate discriminates tenant-aware from tenant-blind governance.)
const MAX_SHED_RATIO: f64 = 0.8;
const ABS_SLACK: f64 = 0.08;
const MIN_ARRIVALS: u64 = 50;

#[test]
fn sustained_2x_overload_sheds_the_hot_tenant_within_the_fairness_bound() {
    // Controller-level open-loop drill, deterministic (no wall clock): a
    // 2× overload is modeled by completing one admitted query per two
    // arrivals — the server has half the capacity the stream demands —
    // with completion latency far past the p99 budget, so every window
    // trips. zipf θ=1.2 over 8 tenants makes tenant 0 the whale (~43%
    // of arrivals against a ~30% capped fair share).
    let classes = TenantClass::derive(8, 1.2);
    let slo = SloConfig { p50_us: 250.0, p95_us: 500.0, p99_us: 1_000.0, max_queue_depth: 32 };
    let ctrl = OverloadController::new(
        OverloadConfig { window: 16, tenants: classes, ..OverloadConfig::for_slo(slo) },
        None,
    );
    let trace = ArrivalGen::new(ArrivalConfig {
        rate_qps: 2_000.0,
        tenants: 8,
        zipf_theta: 1.2,
        seed: 0x0_5ED,
        ..ArrivalConfig::default()
    })
    .generate(1_500_000_000);
    assert!(trace.len() > 2_000, "need a sustained stream, got {}", trace.len());

    let mut arrivals = [0u64; 8];
    let mut shed = [0u64; 8];
    let mut queue: VecDeque<u32> = VecDeque::new();
    for (i, a) in trace.iter().enumerate() {
        arrivals[a.tenant as usize] += 1;
        match ctrl.try_admit_tenant(a.tenant) {
            Ok(_) => queue.push_back(a.tenant),
            Err(rej) => {
                assert_eq!(rej.tenant, a.tenant, "the shed is charged to the arriving tenant");
                shed[a.tenant as usize] += 1;
            }
        }
        // the half-capacity server: one completion per two arrivals,
        // always far over the latency budget (5 ms)
        if i % 2 == 1 {
            if let Some(t) = queue.pop_front() {
                ctrl.on_complete_tenant(t, 5_000_000.0);
            }
        }
    }

    let rep = ctrl.report();
    assert_eq!(rep.rung, Rung::Backpressure, "sustained 2× pegs the ladder");
    let hot = arrivals.iter().enumerate().max_by_key(|(_, n)| **n).unwrap().0;
    assert_eq!(hot, 0, "zipf attribution makes tenant 0 the whale");
    let rate = |t: usize| shed[t] as f64 / arrivals[t] as f64;
    let hot_rate = rate(hot);
    assert!(hot_rate > 0.3, "the over-quota whale must shed hard, got {hot_rate:.3}");
    let bound = MAX_SHED_RATIO * hot_rate + ABS_SLACK;
    for (t, &n) in arrivals.iter().enumerate().skip(1) {
        if n < MIN_ARRIVALS {
            continue;
        }
        assert!(
            rate(t) <= bound,
            "tenant {t} shed {:.3} > fairness bound {bound:.3} (hot {hot_rate:.3})",
            rate(t)
        );
    }
    // every arrival accounted for, and the report agrees per tenant
    let total: u64 = arrivals.iter().sum();
    assert_eq!(rep.admitted + rep.rejected, total);
    let hot_rep = rep.tenants.iter().find(|t| t.tenant == 0).unwrap();
    assert_eq!(hot_rep.admitted + hot_rep.shed, arrivals[0]);
    // the deficit policy's signature: nobody sheds harder than the whale
    for (t, &n) in arrivals.iter().enumerate().skip(1) {
        if n >= MIN_ARRIVALS {
            assert!(rate(t) < hot_rate, "tenant {t} outsheds the whale");
        }
    }
}
