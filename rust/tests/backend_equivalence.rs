//! Backend-equivalence guarantee: the same KV and ANN workloads replayed
//! through every storage backend return *identical results* (keys, values,
//! ids, scores) and differ only in reported timing. This is the contract
//! that makes the storage layer a pure timing/accounting plane — see the
//! `fivemin::storage` module docs.

use std::sync::Arc;

use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{Coordinator, Router, ServingCorpus};
use fivemin::kvstore::{BackedStore, CuckooParams, KvEngine, MemStore};
use fivemin::runtime::default_artifacts_dir;
use fivemin::storage::uring::block_pattern;
use fivemin::storage::{
    BackendSpec, IoClass, IoOp, IoRequest, MemBackend, StorageBackend, UringBackend,
};
use fivemin::util::rng::Rng;

/// Sim backend with a small device geometry so tests run in seconds.
fn small_sim_spec(l_blk: u32) -> BackendSpec {
    BackendSpec::small_sim(l_blk)
}

/// Tempfile-backed uring spec. Compiles and runs with or without
/// `--features uring`: off-feature the portable pread-thread engine
/// serves the same file with the same completions, so this arm keeps the
/// real-file backend under the equivalence contract by default.
fn uring_spec(l_blk: u32) -> BackendSpec {
    BackendSpec::parse("uring", l_blk).unwrap()
}

fn backends(l_blk: u32) -> Vec<BackendSpec> {
    vec![
        BackendSpec::Mem,
        BackendSpec::parse("model", l_blk).unwrap(),
        small_sim_spec(l_blk),
        uring_spec(l_blk),
    ]
}

// ---------------------------------------------------------------------------
// KV engine: GET results must match across backends; timing must not.
// ---------------------------------------------------------------------------

fn run_kv_workload(spec: &BackendSpec) -> (Vec<Option<u64>>, u64, f64) {
    let n_items = 3_000u64;
    let p = CuckooParams::for_capacity(n_items, 0.7, 512, 64);
    let store = BackedStore::new(
        MemStore::new(p.n_buckets, p.slots_per_bucket),
        spec.build(),
    );
    // no engine-side cache: every GET reaches the block store
    let mut e = KvEngine::new(p, store, 128);
    for k in 1..=n_items {
        e.put(k, k.wrapping_mul(0x9E37_79B9));
    }
    e.flush();
    let mut rng = Rng::new(1234);
    let mut results = Vec::new();
    for _ in 0..2_000 {
        let key = 1 + rng.below(n_items + 500); // some misses
        results.push(e.get(key));
    }
    let snap = e.store.snapshot();
    let reads = snap.stats.reads;
    let read_p50 = snap.stats.read_device_ns.percentile(0.5);
    (results, reads, read_p50)
}

#[test]
fn kv_results_identical_across_backends_timing_differs() {
    let runs: Vec<_> = backends(512).iter().map(run_kv_workload).collect();
    let (mem_res, mem_reads, mem_p50) = &runs[0];
    for (i, (res, reads, _)) in runs.iter().enumerate().skip(1) {
        assert_eq!(res, mem_res, "backend #{i} returned different values");
        assert_eq!(reads, mem_reads, "same workload => same I/O count");
    }
    // timing differs: device backends are orders of magnitude slower than
    // the DRAM-class mem backend (SLC sensing alone is 5us vs 100ns)
    let (_, _, model_p50) = &runs[1];
    let (_, _, sim_p50) = &runs[2];
    assert!(
        *model_p50 > 10.0 * mem_p50,
        "model p50 {model_p50}ns vs mem {mem_p50}ns"
    );
    assert!(
        *sim_p50 > 10.0 * mem_p50,
        "sim p50 {sim_p50}ns vs mem {mem_p50}ns"
    );
    // the uring arm (runs[3]) reports *real* wall-clock pread/io_uring
    // latency, which depends on the host filesystem — its results and
    // I/O counts are pinned by the loop above, its timing is not.
}

// ---------------------------------------------------------------------------
// ANN serving: per-query ids/scores must match across backends.
// ---------------------------------------------------------------------------

fn run_ann_workload(spec: BackendSpec, corpus: &Arc<ServingCorpus>) -> Vec<(Vec<u32>, Vec<f32>)> {
    let co = Coordinator::start(
        default_artifacts_dir(),
        corpus.clone(),
        BatchPolicy::default(),
        spec,
    )
    .unwrap();
    let mut rng = Rng::new(77);
    let mut out = Vec::new();
    // sequential queries: each batch holds exactly one query, so results
    // are independent of batch-timing nondeterminism
    for _ in 0..6 {
        let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
        let res = co.query(q).unwrap();
        out.push((res.ids, res.scores));
    }
    out
}

#[test]
fn ann_results_identical_across_backends() {
    let corpus = Arc::new(ServingCorpus::synthetic(1, 55));
    let mut all = Vec::new();
    for spec in backends(4096) {
        all.push(run_ann_workload(spec, &corpus));
    }
    assert_eq!(all[0], all[1], "model backend changed ANN answers");
    assert_eq!(all[0], all[2], "sim backend changed ANN answers");
    assert_eq!(all[0], all[3], "uring backend changed ANN answers");
}

// ---------------------------------------------------------------------------
// Uring backend: identical completions to mem on the same request stream,
// and the payload plane round-trips real bytes through the tempfile.
// ---------------------------------------------------------------------------

#[test]
fn uring_completions_match_mem_and_round_trip_real_bytes() {
    let l_blk = 512u32;
    // mixed stream: writes (one lba rewritten), then general + stage-2
    // reads, including one block never written
    let writes = vec![
        IoRequest::write(3),
        IoRequest::write(7),
        IoRequest::write(11),
        IoRequest::write(3),
    ];
    let reads = vec![
        IoRequest::read(3),
        IoRequest::stage2_read(7),
        IoRequest::read(5),
        IoRequest::stage2_read(11),
    ];

    let run = |backend: &mut dyn StorageBackend| {
        backend.submit(&writes);
        let mut done = backend.wait_all();
        backend.submit(&reads);
        done.extend(backend.wait_all());
        // completion *sets* must match; arrival order may differ between
        // a synchronous mem backend and a threaded/ring engine
        done.sort_by_key(|c| c.id);
        done.iter().map(|c| (c.id, c.op, c.lba, c.class)).collect::<Vec<_>>()
    };

    let mut mem = MemBackend::new();
    let mem_done = run(&mut mem);
    let mut ur = UringBackend::open_temp(64, l_blk).expect("tempfile backend");
    let ur_done = run(&mut ur);
    assert_eq!(
        ur_done, mem_done,
        "uring completions (id/op/lba/class) diverged from mem"
    );

    // payload plane: every read completion carries the actual file bytes —
    // written blocks return their deterministic pattern, the untouched
    // block reads back as zeros from the sparse file
    for (id, op, lba, _) in &ur_done {
        if *op != IoOp::Read {
            continue;
        }
        let pay = ur.take_payload(*id).expect("read completion carries a payload");
        assert_eq!(pay.len(), l_blk as usize);
        if *lba == 5 {
            assert!(pay.iter().all(|&b| b == 0), "unwritten block must read as zeros");
        } else {
            assert_eq!(pay, block_pattern(*lba, l_blk), "lba {lba} bytes corrupted in flight");
        }
        assert!(ur.take_payload(*id).is_none(), "payloads are take-once");
    }
    // stage-2 class was echoed through the real-file path too
    let stage2 = ur_done.iter().filter(|(_, _, _, c)| *c == IoClass::Stage2).count();
    assert_eq!(stage2, 2, "stage-2 tags lost on the uring path");
}

// ---------------------------------------------------------------------------
// Sharded / partitioned serving: the scale-out path must return the exact
// answers of the single-replica path, only timing may differ.
// ---------------------------------------------------------------------------

#[test]
fn kv_results_identical_on_sharded_backend() {
    let (mem_res, mem_reads, _) = run_kv_workload(&BackendSpec::Mem);
    let p = CuckooParams::for_capacity(3_000, 0.7, 512, 64);
    // 4 mem devices covering buckets + WAL slack, then 4 sim devices
    let sharded_mem = BackendSpec::parse("mem:shards=4", 512)
        .unwrap()
        .for_capacity(2 * p.n_buckets);
    let sharded_sim = BackendSpec::Sharded {
        inner: Box::new(small_sim_spec(512)),
        n_shards: 4,
        lbas_per_shard: (2 * p.n_buckets).div_euclid(4).max(1),
        policy: fivemin::storage::MapPolicy::Contiguous,
    };
    // interleaved map: same results, different device placement
    let interleaved_mem = BackendSpec::parse("mem:shards=4,map=interleave", 512)
        .unwrap()
        .for_capacity(2 * p.n_buckets);
    for (name, spec) in [
        ("mem", sharded_mem),
        ("sim", sharded_sim),
        ("mem-interleave", interleaved_mem),
    ] {
        let (res, reads, _) = run_kv_workload(&spec);
        assert_eq!(res, mem_res, "sharded {name} backend changed GET results");
        assert_eq!(reads, mem_reads, "sharded {name} backend changed I/O count");
    }
}

#[test]
fn partitioned_router_matches_single_replica_worker() {
    let corpus = Arc::new(ServingCorpus::synthetic(4, 91));
    // control arm: one replica worker over the whole corpus, mem backend
    let single = Coordinator::start(
        default_artifacts_dir(),
        corpus.clone(),
        BatchPolicy::default(),
        BackendSpec::Mem,
    )
    .unwrap();
    // treatment arm: 4 partition workers, each owning one shard on its
    // own simulated device
    let workers: Vec<_> = corpus
        .partitions(4)
        .unwrap()
        .into_iter()
        .map(|part| {
            Coordinator::start(
                default_artifacts_dir(),
                Arc::new(part),
                BatchPolicy::default(),
                small_sim_spec(4096),
            )
            .unwrap()
        })
        .collect();
    let router = Router::partitioned(workers).unwrap();
    let mut rng = Rng::new(177);
    for i in 0..6 {
        let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
        let a = single.query(q.clone()).unwrap();
        let b = router.query(q).unwrap();
        assert_eq!(a.ids, b.ids, "query {i}: partitioned ids differ");
        assert_eq!(a.scores, b.scores, "query {i}: partitioned scores differ");
        assert_eq!(a.reduced, b.reduced, "query {i}: partitioned reduced scores differ");
    }
    // partitioned fetches went to the partition devices, not one replica
    let stats = router.stats();
    assert_eq!(stats.len(), 4);
    for (p, s) in stats.iter().enumerate() {
        let snap = s.storage.as_ref().expect("partition snapshot");
        assert!(snap.stats.reads > 0, "partition {p} never touched its device");
    }
}

#[test]
fn sim_backend_reports_device_stats_for_serving() {
    let corpus = Arc::new(ServingCorpus::synthetic(1, 56));
    let co = Coordinator::start(
        default_artifacts_dir(),
        corpus.clone(),
        BatchPolicy::default(),
        small_sim_spec(4096),
    )
    .unwrap();
    let mut rng = Rng::new(57);
    for _ in 0..3 {
        let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
        co.query(q).unwrap();
    }
    let st = co.stats();
    let snap = st.storage.expect("snapshot");
    let dev = snap.device.expect("sim backend exposes device stats");
    assert_eq!(dev.reads_done, snap.stats.reads, "device saw every fetch");
    assert!(dev.read_lat.percentile(0.5) >= 5_000.0, "SLC sense floor");
    assert!(
        st.storage_stall_ns.percentile(0.5) >= 5_000.0,
        "serving stats surface the device stall"
    );
}
