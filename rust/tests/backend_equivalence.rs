//! Backend-equivalence guarantee: the same KV and ANN workloads replayed
//! through every storage backend return *identical results* (keys, values,
//! ids, scores) and differ only in reported timing. This is the contract
//! that makes the storage layer a pure timing/accounting plane — see the
//! `fivemin::storage` module docs.

use std::sync::Arc;

use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{Coordinator, Router, ServingCorpus};
use fivemin::kvstore::{BackedStore, CuckooParams, KvEngine, MemStore};
use fivemin::runtime::default_artifacts_dir;
use fivemin::storage::BackendSpec;
use fivemin::util::rng::Rng;

/// Sim backend with a small device geometry so tests run in seconds.
fn small_sim_spec(l_blk: u32) -> BackendSpec {
    BackendSpec::small_sim(l_blk)
}

fn backends(l_blk: u32) -> Vec<BackendSpec> {
    vec![
        BackendSpec::Mem,
        BackendSpec::parse("model", l_blk).unwrap(),
        small_sim_spec(l_blk),
    ]
}

// ---------------------------------------------------------------------------
// KV engine: GET results must match across backends; timing must not.
// ---------------------------------------------------------------------------

fn run_kv_workload(spec: &BackendSpec) -> (Vec<Option<u64>>, u64, f64) {
    let n_items = 3_000u64;
    let p = CuckooParams::for_capacity(n_items, 0.7, 512, 64);
    let store = BackedStore::new(
        MemStore::new(p.n_buckets, p.slots_per_bucket),
        spec.build(),
    );
    // no engine-side cache: every GET reaches the block store
    let mut e = KvEngine::new(p, store, 128);
    for k in 1..=n_items {
        e.put(k, k.wrapping_mul(0x9E37_79B9));
    }
    e.flush();
    let mut rng = Rng::new(1234);
    let mut results = Vec::new();
    for _ in 0..2_000 {
        let key = 1 + rng.below(n_items + 500); // some misses
        results.push(e.get(key));
    }
    let snap = e.store.snapshot();
    let reads = snap.stats.reads;
    let read_p50 = snap.stats.read_device_ns.percentile(0.5);
    (results, reads, read_p50)
}

#[test]
fn kv_results_identical_across_backends_timing_differs() {
    let runs: Vec<_> = backends(512).iter().map(run_kv_workload).collect();
    let (mem_res, mem_reads, mem_p50) = &runs[0];
    for (i, (res, reads, _)) in runs.iter().enumerate().skip(1) {
        assert_eq!(res, mem_res, "backend #{i} returned different values");
        assert_eq!(reads, mem_reads, "same workload => same I/O count");
    }
    // timing differs: device backends are orders of magnitude slower than
    // the DRAM-class mem backend (SLC sensing alone is 5us vs 100ns)
    let (_, _, model_p50) = &runs[1];
    let (_, _, sim_p50) = &runs[2];
    assert!(
        *model_p50 > 10.0 * mem_p50,
        "model p50 {model_p50}ns vs mem {mem_p50}ns"
    );
    assert!(
        *sim_p50 > 10.0 * mem_p50,
        "sim p50 {sim_p50}ns vs mem {mem_p50}ns"
    );
}

// ---------------------------------------------------------------------------
// ANN serving: per-query ids/scores must match across backends.
// ---------------------------------------------------------------------------

fn run_ann_workload(spec: BackendSpec, corpus: &Arc<ServingCorpus>) -> Vec<(Vec<u32>, Vec<f32>)> {
    let co = Coordinator::start(
        default_artifacts_dir(),
        corpus.clone(),
        BatchPolicy::default(),
        spec,
    )
    .unwrap();
    let mut rng = Rng::new(77);
    let mut out = Vec::new();
    // sequential queries: each batch holds exactly one query, so results
    // are independent of batch-timing nondeterminism
    for _ in 0..6 {
        let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
        let res = co.query(q).unwrap();
        out.push((res.ids, res.scores));
    }
    out
}

#[test]
fn ann_results_identical_across_backends() {
    let corpus = Arc::new(ServingCorpus::synthetic(1, 55));
    let mut all = Vec::new();
    for spec in backends(4096) {
        all.push(run_ann_workload(spec, &corpus));
    }
    assert_eq!(all[0], all[1], "model backend changed ANN answers");
    assert_eq!(all[0], all[2], "sim backend changed ANN answers");
}

// ---------------------------------------------------------------------------
// Sharded / partitioned serving: the scale-out path must return the exact
// answers of the single-replica path, only timing may differ.
// ---------------------------------------------------------------------------

#[test]
fn kv_results_identical_on_sharded_backend() {
    let (mem_res, mem_reads, _) = run_kv_workload(&BackendSpec::Mem);
    let p = CuckooParams::for_capacity(3_000, 0.7, 512, 64);
    // 4 mem devices covering buckets + WAL slack, then 4 sim devices
    let sharded_mem = BackendSpec::parse("mem:shards=4", 512)
        .unwrap()
        .for_capacity(2 * p.n_buckets);
    let sharded_sim = BackendSpec::Sharded {
        inner: Box::new(small_sim_spec(512)),
        n_shards: 4,
        lbas_per_shard: (2 * p.n_buckets).div_euclid(4).max(1),
        policy: fivemin::storage::MapPolicy::Contiguous,
    };
    // interleaved map: same results, different device placement
    let interleaved_mem = BackendSpec::parse("mem:shards=4,map=interleave", 512)
        .unwrap()
        .for_capacity(2 * p.n_buckets);
    for (name, spec) in [
        ("mem", sharded_mem),
        ("sim", sharded_sim),
        ("mem-interleave", interleaved_mem),
    ] {
        let (res, reads, _) = run_kv_workload(&spec);
        assert_eq!(res, mem_res, "sharded {name} backend changed GET results");
        assert_eq!(reads, mem_reads, "sharded {name} backend changed I/O count");
    }
}

#[test]
fn partitioned_router_matches_single_replica_worker() {
    let corpus = Arc::new(ServingCorpus::synthetic(4, 91));
    // control arm: one replica worker over the whole corpus, mem backend
    let single = Coordinator::start(
        default_artifacts_dir(),
        corpus.clone(),
        BatchPolicy::default(),
        BackendSpec::Mem,
    )
    .unwrap();
    // treatment arm: 4 partition workers, each owning one shard on its
    // own simulated device
    let workers: Vec<_> = corpus
        .partitions(4)
        .unwrap()
        .into_iter()
        .map(|part| {
            Coordinator::start(
                default_artifacts_dir(),
                Arc::new(part),
                BatchPolicy::default(),
                small_sim_spec(4096),
            )
            .unwrap()
        })
        .collect();
    let router = Router::partitioned(workers).unwrap();
    let mut rng = Rng::new(177);
    for i in 0..6 {
        let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
        let a = single.query(q.clone()).unwrap();
        let b = router.query(q).unwrap();
        assert_eq!(a.ids, b.ids, "query {i}: partitioned ids differ");
        assert_eq!(a.scores, b.scores, "query {i}: partitioned scores differ");
        assert_eq!(a.reduced, b.reduced, "query {i}: partitioned reduced scores differ");
    }
    // partitioned fetches went to the partition devices, not one replica
    let stats = router.stats();
    assert_eq!(stats.len(), 4);
    for (p, s) in stats.iter().enumerate() {
        let snap = s.storage.as_ref().expect("partition snapshot");
        assert!(snap.stats.reads > 0, "partition {p} never touched its device");
    }
}

#[test]
fn sim_backend_reports_device_stats_for_serving() {
    let corpus = Arc::new(ServingCorpus::synthetic(1, 56));
    let co = Coordinator::start(
        default_artifacts_dir(),
        corpus.clone(),
        BatchPolicy::default(),
        small_sim_spec(4096),
    )
    .unwrap();
    let mut rng = Rng::new(57);
    for _ in 0..3 {
        let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
        co.query(q).unwrap();
    }
    let st = co.stats();
    let snap = st.storage.expect("snapshot");
    let dev = snap.device.expect("sim backend exposes device stats");
    assert_eq!(dev.reads_done, snap.stats.reads, "device saw every fetch");
    assert!(dev.read_lat.percentile(0.5) >= 5_000.0, "SLC sense floor");
    assert!(
        st.storage_stall_ns.percentile(0.5) >= 5_000.0,
        "serving stats surface the device stall"
    );
}
