//! Backend-equivalence guarantee: the same KV and ANN workloads replayed
//! through every storage backend return *identical results* (keys, values,
//! ids, scores) and differ only in reported timing. This is the contract
//! that makes the storage layer a pure timing/accounting plane — see the
//! `fivemin::storage` module docs.

use std::sync::Arc;

use fivemin::config::{NandKind, SsdConfig};
use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{Coordinator, ServingCorpus};
use fivemin::kvstore::{BackedStore, CuckooParams, KvEngine, MemStore};
use fivemin::runtime::default_artifacts_dir;
use fivemin::sim::SimParams;
use fivemin::storage::{BackendSpec, Pace};
use fivemin::util::rng::Rng;

/// Sim backend with a small device geometry so tests run in seconds.
fn small_sim_spec(l_blk: u32) -> BackendSpec {
    let mut cfg = SsdConfig::storage_next(NandKind::Slc);
    cfg.n_ch = 2;
    let mut prm = SimParams::default_for(l_blk);
    prm.blocks_per_plane = 8;
    prm.pages_per_block = 8;
    BackendSpec::Sim { cfg, prm, pace: Pace::Afap }
}

fn backends(l_blk: u32) -> Vec<BackendSpec> {
    vec![
        BackendSpec::Mem,
        BackendSpec::parse("model", l_blk).unwrap(),
        small_sim_spec(l_blk),
    ]
}

// ---------------------------------------------------------------------------
// KV engine: GET results must match across backends; timing must not.
// ---------------------------------------------------------------------------

fn run_kv_workload(spec: &BackendSpec) -> (Vec<Option<u64>>, u64, f64) {
    let n_items = 3_000u64;
    let p = CuckooParams::for_capacity(n_items, 0.7, 512, 64);
    let store = BackedStore::new(
        MemStore::new(p.n_buckets, p.slots_per_bucket),
        spec.build(),
    );
    // tiny cache so most GETs reach the block store
    let mut e = KvEngine::new(p, store, 64, 128);
    for k in 1..=n_items {
        e.put(k, k.wrapping_mul(0x9E37_79B9));
    }
    e.flush();
    let mut rng = Rng::new(1234);
    let mut results = Vec::new();
    for _ in 0..2_000 {
        let key = 1 + rng.below(n_items + 500); // some misses
        results.push(e.get(key));
    }
    let snap = e.store.snapshot();
    let reads = snap.stats.reads;
    let read_p50 = snap.stats.read_device_ns.percentile(0.5);
    (results, reads, read_p50)
}

#[test]
fn kv_results_identical_across_backends_timing_differs() {
    let runs: Vec<_> = backends(512).iter().map(run_kv_workload).collect();
    let (mem_res, mem_reads, mem_p50) = &runs[0];
    for (i, (res, reads, _)) in runs.iter().enumerate().skip(1) {
        assert_eq!(res, mem_res, "backend #{i} returned different values");
        assert_eq!(reads, mem_reads, "same workload => same I/O count");
    }
    // timing differs: device backends are orders of magnitude slower than
    // the DRAM-class mem backend (SLC sensing alone is 5us vs 100ns)
    let (_, _, model_p50) = &runs[1];
    let (_, _, sim_p50) = &runs[2];
    assert!(
        *model_p50 > 10.0 * mem_p50,
        "model p50 {model_p50}ns vs mem {mem_p50}ns"
    );
    assert!(
        *sim_p50 > 10.0 * mem_p50,
        "sim p50 {sim_p50}ns vs mem {mem_p50}ns"
    );
}

// ---------------------------------------------------------------------------
// ANN serving: per-query ids/scores must match across backends.
// ---------------------------------------------------------------------------

fn run_ann_workload(spec: BackendSpec, corpus: &Arc<ServingCorpus>) -> Vec<(Vec<u32>, Vec<f32>)> {
    let co = Coordinator::start(
        default_artifacts_dir(),
        corpus.clone(),
        BatchPolicy::default(),
        spec,
    )
    .unwrap();
    let mut rng = Rng::new(77);
    let mut out = Vec::new();
    // sequential queries: each batch holds exactly one query, so results
    // are independent of batch-timing nondeterminism
    for _ in 0..6 {
        let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
        let res = co.query(q).unwrap();
        out.push((res.ids, res.scores));
    }
    out
}

#[test]
fn ann_results_identical_across_backends() {
    let corpus = Arc::new(ServingCorpus::synthetic(1, 55));
    let mut all = Vec::new();
    for spec in backends(4096) {
        all.push(run_ann_workload(spec, &corpus));
    }
    assert_eq!(all[0], all[1], "model backend changed ANN answers");
    assert_eq!(all[0], all[2], "sim backend changed ANN answers");
}

#[test]
fn sim_backend_reports_device_stats_for_serving() {
    let corpus = Arc::new(ServingCorpus::synthetic(1, 56));
    let co = Coordinator::start(
        default_artifacts_dir(),
        corpus.clone(),
        BatchPolicy::default(),
        small_sim_spec(4096),
    )
    .unwrap();
    let mut rng = Rng::new(57);
    for _ in 0..3 {
        let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
        co.query(q).unwrap();
    }
    let st = co.stats();
    let snap = st.storage.expect("snapshot");
    let dev = snap.device.expect("sim backend exposes device stats");
    assert_eq!(dev.reads_done, snap.stats.reads, "device saw every fetch");
    assert!(dev.read_lat.percentile(0.5) >= 5_000.0, "SLC sense floor");
    assert!(
        st.storage_stall_ns.percentile(0.5) >= 5_000.0,
        "serving stats surface the device stall"
    );
}
