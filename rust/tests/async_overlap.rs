//! The reactor-phase-2 acceptance proof: a stage-2 fetch burst parks no
//! thread. The serving worker's storage path is submit/sweep — never a
//! blocking `wait_all` — so while a wall-clock-paced sim device holds a
//! fetch burst in flight for hundreds of milliseconds, the *same* worker
//! keeps answering stage-1 reduce legs, and its published backend
//! snapshots show the burst as a live `inflight` gauge the whole time.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{Coordinator, ServingCorpus, WorkerRequest};
use fivemin::runtime::{default_artifacts_dir, SERVE};
use fivemin::storage::{BackendSpec, Pace};
use fivemin::util::rng::Rng;

/// Poll `f` every millisecond until it returns true or `timeout` expires.
fn wait_for(timeout: Duration, mut f: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    f()
}

#[test]
fn worker_answers_reduce_legs_while_a_fetch_burst_is_in_flight() {
    let corpus = Arc::new(ServingCorpus::synthetic(1, 0xA51C));
    // WallClock at 2e-4: the µs-scale virtual burst stretches to roughly
    // a second of wall time — long enough that overlap is unmistakable,
    // short enough for CI.
    let spec = BackendSpec::small_sim(4096)
        .for_capacity(corpus.n as u64)
        .with_pace(Pace::WallClock { speedup: 2e-4 });
    let coord =
        Coordinator::start(default_artifacts_dir(), corpus.clone(), BatchPolicy::default(), spec)
            .unwrap();

    let mut rng = Rng::new(41);
    let k = SERVE.topk;
    let query = corpus.query_near(0, 0.01, &mut rng);
    let ids: Vec<u32> = (0..k as u32).collect();
    let t_submit = Instant::now();
    let frx = coord.submit_request(WorkerRequest::Fetch { query, ids });

    // The submit half publishes a backend snapshot before any completion
    // lands, so the burst must become visible as a live inflight gauge.
    let mut peak_inflight = 0u64;
    assert!(
        wait_for(Duration::from_secs(30), || {
            if let Some(snap) = coord.stats().storage {
                peak_inflight = peak_inflight.max(snap.stats.inflight);
            }
            peak_inflight > 0
        }),
        "fetch burst never showed up in the inflight gauge"
    );
    assert_eq!(peak_inflight, k as u64, "the whole burst is in flight at once");

    // While the device holds the burst, the same worker keeps serving
    // stage-1 reduce legs. If the worker were parked in a blocking
    // wait-for-completions helper, every recv_timeout here would starve.
    let mut overlapped = 0usize;
    for i in 0..4usize {
        let q = corpus.query_near((i * 7) % corpus.n, 0.01, &mut rng);
        let rrx = coord.submit_request(WorkerRequest::Reduce(q));
        let r = rrx
            .recv_timeout(Duration::from_secs(30))
            .expect("reduce leg starved behind the in-flight fetch burst")
            .expect("reduce leg failed");
        assert_eq!(r.ids.len(), k, "reduce answers the local top-k");
        if matches!(frx.try_recv(), Err(mpsc::TryRecvError::Empty)) {
            overlapped += 1;
        }
    }
    assert!(
        overlapped >= 1,
        "no reduce leg answered while the fetch was pending — the worker \
         blocked on the device"
    );

    // The fetch leg itself still completes, with the full accounting: k
    // stage-2 reads charged at completion, a positive device stall, and
    // the inflight gauge back at zero once the sweep absorbs the burst.
    let fr = frx
        .recv_timeout(Duration::from_secs(120))
        .expect("fetch leg lost")
        .expect("fetch leg failed");
    assert_eq!(fr.ids.len(), k);
    let held = t_submit.elapsed();
    assert!(held >= Duration::from_millis(50), "paced burst finished in {held:?} — not paced?");
    assert!(
        wait_for(Duration::from_secs(10), || {
            let st = coord.stats();
            st.ssd_reads == k as u64
                && st.storage.as_ref().is_some_and(|s| s.stats.inflight == 0)
        }),
        "post-completion accounting never settled"
    );
    let st = coord.stats();
    assert_eq!(st.ssd_reads, k as u64, "fetch leg charged exactly k stage-2 reads");
    assert_eq!(st.storage_stall_ns.count(), 1, "one burst, one recorded stall");
    assert!(st.storage_stall_ns.max() > 0.0, "paced device time must surface as storage stall");
    assert_eq!(st.fetch_legs, 1);
}
