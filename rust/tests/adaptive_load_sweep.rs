//! Acceptance pin for the adaptive fetch-mode controller: with MQSim-Next
//! devices behind every partition, `--fetch adaptive` must *track the
//! better static mode* at both ends of the load spectrum — within a
//! bounded factor on stage-2 reads/query and p99 end-to-end latency.
//!
//! "Better" is decided per load level by measured p99 latency of the two
//! static runs (at low load that is speculative — one round-trip; at high
//! load fetch-after-merge — the device is the bottleneck and N× fewer
//! stage-2 reads shortens the tail). The adaptive run then has to stay
//! within `TRACK_FACTOR` (1.25×) of that mode's reads/query *and* p99.
//!
//! Every run gets a warmup phase at its load level (excluded from all
//! metrics; read counts are differenced across the measured phase) so the
//! test asserts the controller's steady-state choice, not its bootstrap.

use std::sync::Arc;
use std::time::Duration;

use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{
    AdaptiveConfig, Coordinator, FetchMode, Router, ServingCorpus,
};
use fivemin::runtime::default_artifacts_dir;
use fivemin::storage::BackendSpec;
use fivemin::util::rng::Rng;
use fivemin::util::stats::Samples;

/// The ISSUE's acceptance bound: adaptive within 1.25x of the better
/// static mode on each metric.
const TRACK_FACTOR: f64 = 1.25;

const N_PARTS: usize = 2;
const WARMUP: usize = 24;
const MEASURED: usize = 128;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Load {
    /// Closed loop, queue depth 1: round-trips dominate, device idles.
    Low,
    /// Open loop, every query in flight at once: the device saturates.
    High,
}

struct RunOut {
    reads_per_query: f64,
    p99_ns: f64,
    merge_share: f64,
}

fn start_router(corpus: &Arc<ServingCorpus>, fetch: FetchMode) -> Router {
    let workers: Vec<Coordinator> = corpus
        .partitions(N_PARTS)
        .expect("partitions")
        .into_iter()
        .map(|part| {
            let spec = BackendSpec::small_sim(4096).for_capacity(part.n as u64);
            Coordinator::start(
                default_artifacts_dir(),
                Arc::new(part),
                BatchPolicy::default(),
                spec,
            )
            .expect("worker starts")
        })
        .collect();
    match fetch {
        // Small window so the controller samples several times within the
        // warmup; rare refresh keeps probe dispatches out of the measured
        // tail (the phase-2 estimate can only go stale-low, which biases
        // toward merge — the safe direction under rising load).
        FetchMode::Adaptive => Router::partitioned_adaptive(
            workers,
            AdaptiveConfig { window: 8, refresh: 32, ..AdaptiveConfig::default() },
        )
        .expect("adaptive router"),
        mode => Router::partitioned_with(workers, mode).expect("router"),
    }
}

/// Serve warmup + measured phases at `load`; metrics cover the measured
/// phase only. p99 is nearest-rank over the per-query e2e latencies.
fn run(corpus: &Arc<ServingCorpus>, fetch: FetchMode, load: Load) -> RunOut {
    let router = start_router(corpus, fetch);
    let mut rng = Rng::new(0xADA_97);
    let mut serve = |n: usize, lat: Option<&mut Samples>| {
        let mut lat = lat;
        let push = |res: fivemin::coordinator::QueryResult, lat: &mut Option<&mut Samples>| {
            if let Some(l) = lat.as_deref_mut() {
                l.push(res.latency.as_nanos() as f64);
            }
        };
        match load {
            Load::Low => {
                for _ in 0..n {
                    let t = rng.below(corpus.n as u64) as usize;
                    let res = router
                        .submit(corpus.query_near(t, 0.02, &mut rng))
                        .recv()
                        .expect("router alive")
                        .expect("query served");
                    push(res, &mut lat);
                }
            }
            Load::High => {
                let pending: Vec<_> = (0..n)
                    .map(|_| {
                        let t = rng.below(corpus.n as u64) as usize;
                        router.submit(corpus.query_near(t, 0.02, &mut rng))
                    })
                    .collect();
                for rx in pending {
                    let res = rx.recv().expect("router alive").expect("query served");
                    push(res, &mut lat);
                }
            }
        }
    };
    serve(WARMUP, None);
    let reads0 = router.settled_stats(Duration::from_secs(10)).ssd_reads;
    let mut lat = Samples::new();
    serve(MEASURED, Some(&mut lat));
    let reads1 = router.settled_stats(Duration::from_secs(10)).ssd_reads;
    RunOut {
        reads_per_query: (reads1 - reads0) as f64 / MEASURED as f64,
        p99_ns: lat.percentile(0.99),
        merge_share: router.adaptive_report().map(|r| r.merge_share()).unwrap_or(0.0),
    }
}

fn assert_tracks(load: Load) {
    let corpus = Arc::new(ServingCorpus::synthetic(2, 0xADA_97));
    let spec = run(&corpus, FetchMode::Speculative, load);
    let merge = run(&corpus, FetchMode::AfterMerge, load);
    let adaptive = run(&corpus, FetchMode::Adaptive, load);
    // "better" static mode at this load = lower measured p99
    let better = if spec.p99_ns <= merge.p99_ns { &spec } else { &merge };
    let better_name = if spec.p99_ns <= merge.p99_ns { "spec" } else { "merge" };
    let diag = format!(
        "load {load:?}: better={better_name} \
         [spec rpq {:.1} p99 {:.0}us | merge rpq {:.1} p99 {:.0}us | \
         adaptive rpq {:.1} p99 {:.0}us, merge_share {:.2}]",
        spec.reads_per_query,
        spec.p99_ns / 1e3,
        merge.reads_per_query,
        merge.p99_ns / 1e3,
        adaptive.reads_per_query,
        adaptive.p99_ns / 1e3,
        adaptive.merge_share
    );
    assert!(
        adaptive.reads_per_query <= TRACK_FACTOR * better.reads_per_query,
        "adaptive reads/query {:.1} > {TRACK_FACTOR} x better mode's {:.1} — {diag}",
        adaptive.reads_per_query,
        better.reads_per_query
    );
    assert!(
        adaptive.p99_ns <= TRACK_FACTOR * better.p99_ns,
        "adaptive p99 {:.0}us > {TRACK_FACTOR} x better mode's {:.0}us — {diag}",
        adaptive.p99_ns / 1e3,
        better.p99_ns / 1e3
    );
    // regardless of which mode won on latency, adaptive can never beat
    // the merge floor or exceed the spec ceiling on reads
    assert!(
        adaptive.reads_per_query >= merge.reads_per_query - 1e-9
            && adaptive.reads_per_query <= spec.reads_per_query + 1e-9,
        "adaptive reads/query outside the static interval — {diag}"
    );
    println!("tracked: {diag}");
}

// Both arms run in the release test pass (CI runs `cargo test --release
// -q` with the same suite). In debug builds they are ignored: the
// controller prices *wall-clock* phase-2 round-trips against *virtual*
// device time, and unoptimized graph execution inflates the round-trip
// side ~30x, swamping exactly the load signal this sweep exercises. The
// functional (profile-independent) properties of the adaptive path are
// covered in both profiles by `router_equivalence_prop.rs` and the
// controller unit tests.

/// Low load: round-trip-bound. Speculative's single round-trip should win
/// on latency and the controller should mostly dispatch speculatively.
#[test]
#[cfg_attr(debug_assertions, ignore = "wall-clock sweep; run under --release")]
fn adaptive_tracks_better_mode_at_low_load() {
    assert_tracks(Load::Low);
}

/// High load: device-bound. After-merge's N x fewer stage-2 reads should
/// win the tail and the controller should mostly dispatch fetch-after-
/// merge.
#[test]
#[cfg_attr(debug_assertions, ignore = "wall-clock sweep; run under --release")]
fn adaptive_tracks_better_mode_at_high_load() {
    assert_tracks(Load::High);
}
