//! End-to-end serving path: coordinator → batcher → PJRT execution of the
//! AOT two-stage graphs (the Layer-1 Pallas kernels inlined in the HLO).
//! Requires `make artifacts`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{Coordinator, Router, ServingCorpus};
use fivemin::runtime::{default_artifacts_dir, SERVE};
use fivemin::util::rng::Rng;

fn artifacts() -> Option<PathBuf> {
    let d = default_artifacts_dir();
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn coordinator_answers_with_high_recall() {
    let Some(dir) = artifacts() else { return };
    let corpus = Arc::new(ServingCorpus::synthetic(2, 11));
    let mut co = Coordinator::start(dir, corpus.clone(), BatchPolicy::default()).unwrap();
    let mut rng = Rng::new(3);
    let trials = 64;
    let mut top1_hits = 0;
    for _ in 0..trials {
        let target = rng.below(corpus.n as u64) as usize;
        let q = corpus.query_near(target, 0.02, &mut rng);
        let res = co.query(q).unwrap();
        assert_eq!(res.ids.len(), SERVE.topk);
        // scores sorted best-first
        assert!(res.scores.windows(2).all(|w| w[0] >= w[1] - 1e-5));
        if res.ids[0] as usize == target {
            top1_hits += 1;
        }
    }
    let recall = top1_hits as f64 / trials as f64;
    assert!(recall >= 0.95, "top-1 recall {recall}");
    let st = co.stats();
    assert_eq!(st.queries, trials);
    assert!(st.batches >= 1);
    co.stop();
}

#[test]
fn batching_amortizes_latency() {
    let Some(dir) = artifacts() else { return };
    let corpus = Arc::new(ServingCorpus::synthetic(1, 13));
    let policy = BatchPolicy { max_batch: SERVE.batch, max_wait: Duration::from_millis(5) };
    let co = Coordinator::start(dir, corpus.clone(), policy).unwrap();
    let mut rng = Rng::new(5);
    // fire a burst of concurrent queries; they should ride shared batches
    let receivers: Vec<_> = (0..SERVE.batch)
        .map(|_| {
            let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
            co.submit(q)
        })
        .collect();
    let mut max_batch_seen = 0;
    for r in receivers {
        let res = r.recv().unwrap().unwrap();
        max_batch_seen = max_batch_seen.max(res.batch_size);
    }
    assert!(
        max_batch_seen > 1,
        "burst should batch together, saw max batch {max_batch_seen}"
    );
    let st = co.stats();
    assert!(st.batches < SERVE.batch as u64, "batches {} queries {}", st.batches, st.queries);
}

#[test]
fn router_spreads_load_across_workers() {
    let Some(dir) = artifacts() else { return };
    let corpus = Arc::new(ServingCorpus::synthetic(1, 17));
    let w1 = Coordinator::start(dir.clone(), corpus.clone(), BatchPolicy::default()).unwrap();
    let w2 = Coordinator::start(dir, corpus.clone(), BatchPolicy::default()).unwrap();
    let router = Router::new(vec![w1, w2]);
    let mut rng = Rng::new(7);
    for _ in 0..16 {
        let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
        router.query(q).unwrap();
    }
    let stats = router.stats();
    assert_eq!(stats.len(), 2);
    assert_eq!(stats.iter().map(|s| s.queries).sum::<u64>(), 16);
    assert!(stats.iter().all(|s| s.queries == 8), "round-robin must halve");
}

#[test]
fn malformed_query_rejected_not_fatal() {
    let Some(dir) = artifacts() else { return };
    let corpus = Arc::new(ServingCorpus::synthetic(1, 19));
    let co = Coordinator::start(dir, corpus.clone(), BatchPolicy::default()).unwrap();
    let err = co.query(vec![1.0; 7]); // wrong dimension
    assert!(err.is_err());
    // worker survives and serves the next query
    let mut rng = Rng::new(23);
    let q = corpus.query_near(0, 0.02, &mut rng);
    assert!(co.query(q).is_ok());
}
