//! End-to-end serving path: coordinator → batcher → two-stage graph
//! execution → storage backend.
//!
//! Runs on the native graph engine (no artifacts needed); when
//! `artifacts/manifest.json` exists and the crate is built with
//! `--features pjrt`, the same tests exercise the PJRT path.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{Coordinator, Router, ServingCorpus};
use fivemin::runtime::{default_artifacts_dir, SERVE};
use fivemin::storage::BackendSpec;
use fivemin::util::rng::Rng;

fn artifacts() -> PathBuf {
    // Missing artifacts fall back to the native engine inside Runtime.
    default_artifacts_dir()
}

fn start(corpus: &Arc<ServingCorpus>, policy: BatchPolicy) -> Coordinator {
    Coordinator::start(artifacts(), corpus.clone(), policy, BackendSpec::Mem).unwrap()
}

#[test]
fn coordinator_answers_with_high_recall() {
    let corpus = Arc::new(ServingCorpus::synthetic(2, 11));
    let mut co = start(&corpus, BatchPolicy::default());
    let mut rng = Rng::new(3);
    let trials = 64;
    // concurrent submission: queries share batches, amortizing the scan
    let pending: Vec<_> = (0..trials)
        .map(|_| {
            let target = rng.below(corpus.n as u64) as usize;
            (target, co.submit(corpus.query_near(target, 0.02, &mut rng)))
        })
        .collect();
    let mut top1_hits = 0;
    for (target, rx) in pending {
        let res = rx.recv().unwrap().unwrap();
        assert_eq!(res.ids.len(), SERVE.topk);
        // scores sorted best-first
        assert!(res.scores.windows(2).all(|w| w[0] >= w[1] - 1e-5));
        if res.ids[0] as usize == target {
            top1_hits += 1;
        }
    }
    let recall = top1_hits as f64 / trials as f64;
    assert!(recall >= 0.95, "top-1 recall {recall}");
    let st = co.stats();
    assert_eq!(st.queries, trials);
    assert!(st.batches >= 1);
    assert!(st.storage.is_some(), "backend snapshot published");
    co.stop();
}

#[test]
fn batching_amortizes_latency() {
    let corpus = Arc::new(ServingCorpus::synthetic(1, 13));
    let policy = BatchPolicy { max_batch: SERVE.batch, max_wait: Duration::from_millis(5) };
    let co = start(&corpus, policy);
    let mut rng = Rng::new(5);
    // fire a burst of concurrent queries; they should ride shared batches
    let receivers: Vec<_> = (0..SERVE.batch)
        .map(|_| {
            let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
            co.submit(q)
        })
        .collect();
    let mut max_batch_seen = 0;
    for r in receivers {
        let res = r.recv().unwrap().unwrap();
        max_batch_seen = max_batch_seen.max(res.batch_size);
    }
    assert!(
        max_batch_seen > 1,
        "burst should batch together, saw max batch {max_batch_seen}"
    );
    let st = co.stats();
    assert!(st.batches < SERVE.batch as u64, "batches {} queries {}", st.batches, st.queries);
}

#[test]
fn router_spreads_load_across_workers() {
    let corpus = Arc::new(ServingCorpus::synthetic(1, 17));
    let w1 = start(&corpus, BatchPolicy::default());
    let w2 = start(&corpus, BatchPolicy::default());
    let router = Router::new(vec![w1, w2]).unwrap();
    let mut rng = Rng::new(7);
    for _ in 0..16 {
        let q = corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng);
        router.query(q).unwrap();
    }
    let stats = router.stats();
    assert_eq!(stats.len(), 2);
    assert_eq!(stats.iter().map(|s| s.queries).sum::<u64>(), 16);
    assert!(stats.iter().all(|s| s.queries == 8), "round-robin must halve");
    // satellite: callers get the aggregate without re-implementing the merge
    let merged = router.merged_stats();
    assert_eq!(merged.queries, 16);
    assert_eq!(merged.latency_ns.count(), 16);
    assert!(merged.storage.is_some(), "aggregate snapshot published");
}

// (empty-router rejection is covered by the unit test in coordinator/mod.rs)

#[test]
fn partitioned_router_scatter_gathers_with_high_recall() {
    let corpus = Arc::new(ServingCorpus::synthetic(2, 21));
    let workers: Vec<_> = corpus
        .partitions(2)
        .unwrap()
        .into_iter()
        .map(|part| {
            Coordinator::start(
                artifacts(),
                Arc::new(part),
                BatchPolicy::default(),
                BackendSpec::Mem,
            )
            .unwrap()
        })
        .collect();
    let router = Router::partitioned(workers).unwrap();
    let mut rng = Rng::new(23);
    let trials = 24u64;
    let mut top1_hits = 0;
    for _ in 0..trials {
        let target = rng.below(corpus.n as u64) as usize;
        let res = router
            .query(corpus.query_near(target, 0.02, &mut rng))
            .unwrap();
        assert_eq!(res.ids.len(), SERVE.topk);
        assert_eq!(res.reduced.len(), SERVE.topk);
        assert!(res.scores.windows(2).all(|w| w[0] >= w[1] - 1e-5));
        if res.ids[0] as usize == target {
            top1_hits += 1;
        }
    }
    let recall = top1_hits as f64 / trials as f64;
    assert!(recall >= 0.9, "top-1 recall {recall}");
    // scatter: every partition served every query
    let stats = router.stats();
    assert_eq!(stats.len(), 2);
    assert!(stats.iter().all(|s| s.queries == trials));
    let merged = router.merged_stats();
    assert_eq!(merged.queries, 2 * trials);
    let snap = merged.storage.expect("aggregate snapshot");
    assert_eq!(snap.shards.len(), 2, "per-partition snapshots preserved");
}

/// `Router::settled_stats` on an already-settled router must return as
/// soon as the storage snapshot reconciles with the coordinator-side
/// read counters — not after a fixed poll sleep. The serial queries
/// below guarantee every fetch burst's snapshot has landed before the
/// call, so the generous timeout must never be approached.
#[test]
fn settled_stats_returns_immediately_once_reconciled() {
    let corpus = Arc::new(ServingCorpus::synthetic(2, 37));
    let workers: Vec<_> = corpus
        .partitions(2)
        .unwrap()
        .into_iter()
        .map(|part| {
            Coordinator::start(
                artifacts(),
                Arc::new(part),
                BatchPolicy::default(),
                BackendSpec::Mem,
            )
            .unwrap()
        })
        .collect();
    let router = Router::partitioned(workers).unwrap();
    let mut rng = Rng::new(41);
    for _ in 0..8 {
        // blocking queries: each answer implies its batch completed, and
        // a follow-up stats() read forces the snapshot to be visible
        router
            .query(corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng))
            .unwrap();
    }
    // settle once (absorbing any final in-flight snapshot), then time
    // the already-settled call: it must be instant, far under timeout
    let st = router.settled_stats(Duration::from_secs(10));
    assert!(st.storage.is_some(), "settled stats carry the snapshot");
    let t0 = std::time::Instant::now();
    let again = router.settled_stats(Duration::from_secs(10));
    let dt = t0.elapsed();
    assert_eq!(again.ssd_reads, st.ssd_reads, "stable counters on a quiet router");
    assert!(
        dt < Duration::from_millis(500),
        "settled router took {dt:?} — settled_stats must return on reconciliation, \
         not wait out a poll interval"
    );
}

#[test]
fn malformed_query_rejected_not_fatal() {
    let corpus = Arc::new(ServingCorpus::synthetic(1, 19));
    let co = start(&corpus, BatchPolicy::default());
    let err = co.query(vec![1.0; 7]); // wrong dimension
    assert!(err.is_err());
    // worker survives and serves the next query
    let mut rng = Rng::new(23);
    let q = corpus.query_near(0, 0.02, &mut rng);
    assert!(co.query(q).is_ok());
}

#[test]
fn serving_charges_storage_reads() {
    let corpus = Arc::new(ServingCorpus::synthetic(1, 29));
    let co = start(&corpus, BatchPolicy::default());
    let mut rng = Rng::new(31);
    for _ in 0..4 {
        co.query(corpus.query_near(rng.below(corpus.n as u64) as usize, 0.02, &mut rng))
            .unwrap();
    }
    let st = co.stats();
    let snap = st.storage.expect("snapshot");
    assert_eq!(
        snap.stats.reads,
        4 * SERVE.topk as u64,
        "one backend read per promoted candidate"
    );
    assert!(st.storage_stall_ns.count() >= 1, "per-batch stall recorded");
}
