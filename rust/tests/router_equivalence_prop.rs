//! Randomized pin of the partitioned-router equivalence guarantee.
//!
//! PR 2 proved bit-identical merge on two hand-picked cases; this suite
//! turns that into a randomized property: across seeded trials with
//! random corpus sizes, partition counts, and per-worker storage-shard
//! counts, the answers of {one replica worker} × {partitioned,
//! speculative fetch} × {partitioned, fetch-after-merge} ×
//! {partitioned, adaptive} must be bit-identical (ids, full scores,
//! reduced scores), and the I/O accounting must show after-merge issuing
//! exactly `1/N` of the speculative stage-2 device reads — with the
//! adaptive arm landing between those two exact costs whatever mix of
//! modes its controller dispatched.
//!
//! (`k` itself is pinned by the AOT graph shape (`SERVE.topk`), so the
//! randomization varies everything the protocol is generic over: corpus
//! shards, partition fan-out, storage fan-out, query count, noise, and
//! seeds. Replay a failure with the `FIVEMIN_PROP_SEED` env var.)
//!
//! A fourth arm runs each trial's queries with a DRAM tier
//! (`storage::TieredBackend`) in front of every worker's backend at a
//! randomized capacity/rule/fetch-mode: answers must stay bit-identical
//! (the tier is a timing plane) and the accounting must be exact —
//! `device reads == tier misses`, `tier hits + misses == submitted
//! stage-2 reads`. A KV arm pins GET equivalence through the migrated
//! `BackedStore` the same way.
//!
//! A fifth arm pins the selective-routing safety nets: with a routed
//! (`topm:M`) router forced into all-probes (`probe_every = 1`) or
//! all-escalations (huge `escalate_margin`), every answer must stay
//! bit-identical to the unrouted control — full coverage through either
//! net must reach the same merge. Dedicated tests below pin the
//! degenerate `M = N` router against today's router on both seams and
//! hold the live `probe_recall` floor (≥ 0.95) at `M = N/2` under zipf
//! traffic on a clustered corpus.

use std::sync::Arc;
use std::time::Duration;

use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{
    AffinityPredictor, Coordinator, FetchMode, QueryResult, ReactorConfig, RouteConfig,
    RouteSpec, Router, ServingCorpus,
};
use fivemin::runtime::{default_artifacts_dir, SERVE};
use fivemin::storage::{BackendSpec, TierRule, TierSpec};
use fivemin::util::proptest::Prop;
use fivemin::util::rng::Rng;

#[derive(Debug)]
struct Trial {
    corpus_shards: usize,
    n_parts: usize,
    /// Storage shards *per worker* (`mem:shards=S` fan-out), on top of
    /// the worker-level partitioning.
    backend_shards: usize,
    n_queries: usize,
    corpus_seed: u64,
    query_seed: u64,
    noise: f32,
    /// Tiered-arm parameters: per-worker DRAM capacity (MB), admission
    /// rule, and the fetch protocol the tiered router runs.
    tier_mb: u64,
    tier_rule: TierRule,
    tier_fetch: FetchMode,
    /// Reactor-arm admission window. Small values force queries to queue
    /// in the inbox behind the window — the equivalence claim must hold
    /// under that pressure too.
    admission: usize,
    /// Routed-arm fan-out (`topm:route_m`), 1..=n_parts.
    route_m: usize,
    /// Routed-arm seam: the probe/escalation bit-identity claims must
    /// hold on both, so trials alternate.
    route_reactor: bool,
}

fn gen_trial(rng: &mut Rng) -> Trial {
    // Weighted toward small corpora (synthetic generation dominates the
    // trial cost); 4-shard cases keep the deep fan-outs honest.
    let corpus_shards = match rng.below(100) {
        0..=54 => 1,
        55..=84 => 2,
        _ => 4,
    };
    let divisors: Vec<usize> = (1..=corpus_shards)
        .filter(|d| corpus_shards % d == 0)
        .collect();
    let n_parts = divisors[rng.below(divisors.len() as u64) as usize];
    Trial {
        corpus_shards,
        n_parts,
        backend_shards: [1usize, 2, 4][rng.below(3) as usize],
        n_queries: 2 + rng.below(2) as usize,
        corpus_seed: rng.below(1 << 20),
        query_seed: rng.below(1 << 20),
        noise: 0.01 + 0.04 * rng.f64() as f32,
        tier_mb: [1u64, 4, 64][rng.below(3) as usize],
        tier_rule: [TierRule::Clock, TierRule::Breakeven][rng.below(2) as usize],
        tier_fetch: [FetchMode::Speculative, FetchMode::AfterMerge][rng.below(2) as usize],
        admission: [1usize, 2, 4096][rng.below(3) as usize],
        route_m: 1 + rng.below(n_parts as u64) as usize,
        route_reactor: rng.below(2) == 1,
    }
}

/// Submit all queries concurrently (they may share batches — results are
/// per-query deterministic regardless) and collect in submission order.
fn serve_all(
    submit: impl Fn(Vec<f32>) -> std::sync::mpsc::Receiver<Result<QueryResult, String>>,
    queries: &[Vec<f32>],
) -> Result<Vec<QueryResult>, String> {
    let pending: Vec<_> = queries.iter().map(|q| submit(q.clone())).collect();
    let mut out = Vec::with_capacity(pending.len());
    for rx in pending {
        out.push(rx.recv().map_err(|_| "worker gone".to_string())??);
    }
    Ok(out)
}

/// Settle window for `Router::settled_stats` (workers answer before
/// capturing the batch's backend snapshot).
const SETTLE: Duration = Duration::from_secs(10);

fn start_single(corpus: &Arc<ServingCorpus>) -> Result<Coordinator, String> {
    Coordinator::start(
        default_artifacts_dir(),
        corpus.clone(),
        BatchPolicy::default(),
        BackendSpec::Mem,
    )
    .map_err(|e| e.to_string())
}

fn start_router(
    corpus: &Arc<ServingCorpus>,
    n_parts: usize,
    worker_spec: &BackendSpec,
    fetch: FetchMode,
    reactor: Option<ReactorConfig>,
) -> Result<Router, String> {
    let workers = corpus
        .partitions(n_parts)
        .map_err(|e| e.to_string())?
        .into_iter()
        .map(|part| {
            let spec = worker_spec.clone().for_capacity(part.n as u64);
            Coordinator::start(
                default_artifacts_dir(),
                Arc::new(part),
                BatchPolicy::default(),
                spec,
            )
        })
        .collect::<anyhow::Result<Vec<_>>>()
        .map_err(|e| e.to_string())?;
    match reactor {
        Some(cfg) => Router::partitioned_reactor(workers, fetch, cfg),
        None => Router::partitioned_with(workers, fetch),
    }
    .map_err(|e| e.to_string())
}

/// Start a heat-aware routed router (fetch-after-merge — routed scatters
/// force it anyway) with the given routing config, on either seam.
fn start_routed(
    corpus: &Arc<ServingCorpus>,
    n_parts: usize,
    worker_spec: &BackendSpec,
    cfg: RouteConfig,
    reactor: Option<ReactorConfig>,
) -> Result<Router, String> {
    let parts = corpus.partitions(n_parts).map_err(|e| e.to_string())?;
    let pred =
        Arc::new(AffinityPredictor::from_partitions(&parts, cfg).map_err(|e| e.to_string())?);
    let workers = parts
        .into_iter()
        .map(|part| {
            let spec = worker_spec.clone().for_capacity(part.n as u64);
            Coordinator::start(
                default_artifacts_dir(),
                Arc::new(part),
                BatchPolicy::default(),
                spec,
            )
        })
        .collect::<anyhow::Result<Vec<_>>>()
        .map_err(|e| e.to_string())?;
    match reactor {
        Some(rc) => Router::partitioned_reactor_routed(workers, FetchMode::AfterMerge, rc, pred),
        None => Router::partitioned_routed(workers, FetchMode::AfterMerge, pred),
    }
    .map_err(|e| e.to_string())
}

fn check_trial(t: &Trial) -> Result<(), String> {
    let k = SERVE.topk as u64;
    let corpus = Arc::new(ServingCorpus::synthetic(t.corpus_shards, t.corpus_seed));
    let mut qrng = Rng::new(t.query_seed);
    let queries: Vec<Vec<f32>> = (0..t.n_queries)
        .map(|_| corpus.query_near(qrng.below(corpus.n as u64) as usize, t.noise, &mut qrng))
        .collect();

    // control arm: one replica worker over the whole corpus, mem backend
    let single = start_single(&corpus)?;
    let base = serve_all(|q| single.submit(q), &queries)?;

    let worker_spec = if t.backend_shards == 1 {
        BackendSpec::Mem
    } else {
        BackendSpec::parse(&format!("mem:shards={}", t.backend_shards), 4096)
            .map_err(|e| e.to_string())?
    };

    for fetch in [FetchMode::Speculative, FetchMode::AfterMerge, FetchMode::Adaptive] {
        // Both serving seams must produce the same bits AND the same
        // exact read accounting — the reactor arm additionally runs with
        // the trial's (possibly tiny) admission window, so equivalence
        // holds when queries queue in the inbox behind it.
        for reactor in [None, Some(ReactorConfig { admission: t.admission, ..Default::default() })]
        {
            let seam = if reactor.is_some() { "reactor" } else { "threads" };
            let router = start_router(&corpus, t.n_parts, &worker_spec, fetch, reactor)?;
            if router.serve_mode() != seam {
                return Err(format!("router reports seam {}, want {seam}", router.serve_mode()));
            }
            let got = serve_all(|q| router.submit(q), &queries)?;
            for (qi, (a, b)) in base.iter().zip(&got).enumerate() {
                if a.ids != b.ids {
                    return Err(format!("{}/{seam} ids differ on query {qi}", fetch.name()));
                }
                if a.scores != b.scores {
                    return Err(format!(
                        "{}/{seam} full scores differ on query {qi}",
                        fetch.name()
                    ));
                }
                if a.reduced != b.reduced {
                    return Err(format!(
                        "{}/{seam} reduced scores differ on query {qi}",
                        fetch.name()
                    ));
                }
            }
            if let Some(rep) = router.reactor_report() {
                if rep.completed != t.n_queries as u64 {
                    return Err(format!(
                        "reactor completed {} of {} queries",
                        rep.completed, t.n_queries
                    ));
                }
                if rep.peak_pending > t.admission as u64 {
                    return Err(format!(
                        "reactor peak pending {} exceeded admission window {}",
                        rep.peak_pending, t.admission
                    ));
                }
            }
            // I/O accounting: speculative fetches k per query per
            // partition, after-merge exactly k per query in total. The
            // adaptive arm dispatches a measurement-dependent mix, so its
            // total must land in the closed interval the static modes pin
            // down — and the device-side counter must agree with the
            // coordinator's exactly.
            let st = router.settled_stats(SETTLE);
            let merge_want = t.n_queries as u64 * k;
            let spec_want = merge_want * t.n_parts as u64;
            let snap = st.storage.as_ref().ok_or("missing storage snapshot")?;
            match fetch {
                FetchMode::Adaptive => {
                    if st.ssd_reads < merge_want || st.ssd_reads > spec_want {
                        return Err(format!(
                            "adaptive/{seam} issued {} stage-2 reads, outside \
                             [{merge_want}, {spec_want}]",
                            st.ssd_reads
                        ));
                    }
                    if snap.stats.stage2_reads != st.ssd_reads {
                        return Err(format!(
                            "adaptive/{seam} backend counted {} stage-2 reads, coordinator {}",
                            snap.stats.stage2_reads, st.ssd_reads
                        ));
                    }
                }
                _ => {
                    let want =
                        if fetch == FetchMode::Speculative { spec_want } else { merge_want };
                    if st.ssd_reads != want {
                        return Err(format!(
                            "{}/{seam} issued {} stage-2 reads, want {want}",
                            fetch.name(),
                            st.ssd_reads
                        ));
                    }
                    if snap.stats.stage2_reads != want {
                        return Err(format!(
                            "{}/{seam} backend counted {} stage-2 reads, want {want}",
                            fetch.name(),
                            snap.stats.stage2_reads
                        ));
                    }
                }
            }
            if fetch == FetchMode::AfterMerge {
                let legs = st.reduce_legs;
                let expect_legs = (t.n_queries * t.n_parts) as u64;
                if legs != expect_legs {
                    return Err(format!("{seam}: {legs} reduce legs, want {expect_legs}"));
                }
            }
        }
    }

    // ---- tiered arm: DRAM tier in front of every worker's backend ----
    let tier = TierSpec { rate: 1_000.0, ..TierSpec::new(t.tier_mb, t.tier_rule, 4096) };
    let label = tier.label();
    let tiered_spec = worker_spec.clone().tiered(tier);
    let router = start_router(&corpus, t.n_parts, &tiered_spec, t.tier_fetch, None)?;
    let got = serve_all(|q| router.submit(q), &queries)?;
    for (qi, (a, b)) in base.iter().zip(&got).enumerate() {
        if a.ids != b.ids || a.scores != b.scores || a.reduced != b.reduced {
            return Err(format!(
                "{label}/{} answers differ on query {qi} — the tier must be a pure \
                 timing plane",
                t.tier_fetch.name()
            ));
        }
    }
    let st = router.settled_stats(SETTLE);
    let snap = st.storage.as_ref().ok_or("missing tiered storage snapshot")?;
    let ts = snap.stats.tier.as_ref().ok_or("missing tier stats in snapshot")?;
    if ts.hits + ts.misses != st.ssd_reads {
        return Err(format!(
            "{label}: {} hits + {} misses != {} submitted stage-2 reads",
            ts.hits, ts.misses, st.ssd_reads
        ));
    }
    if snap.stats.reads != ts.misses {
        return Err(format!(
            "{label}: {} device reads != {} tier misses",
            snap.stats.reads, ts.misses
        ));
    }
    if snap.stats.stage2_reads + ts.stage2_hits != st.ssd_reads {
        return Err(format!(
            "{label}: device stage-2 {} + stage-2 hits {} != submitted {}",
            snap.stats.stage2_reads, ts.stage2_hits, st.ssd_reads
        ));
    }

    // ---- routed arm: either safety net forced wide open means every
    // query gets full shard coverage, so answers must match the unrouted
    // control bit for bit — probes via the deterministic cadence,
    // escalations via an unbeatable margin. heat_blend = 0 keeps the
    // predictor a pure function of the query (order-insensitive).
    for (net, rcfg) in [
        (
            "all-probes",
            RouteConfig { probe_every: 1, heat_blend: 0.0, ..RouteConfig::top_m(t.route_m) },
        ),
        (
            "all-escalations",
            RouteConfig {
                probe_every: 0,
                escalate_margin: 1e9,
                heat_blend: 0.0,
                ..RouteConfig::top_m(t.route_m)
            },
        ),
    ] {
        let reactor = t
            .route_reactor
            .then(|| ReactorConfig { admission: t.admission, ..Default::default() });
        let seam = if reactor.is_some() { "reactor" } else { "threads" };
        let router = start_routed(&corpus, t.n_parts, &worker_spec, rcfg, reactor)?;
        let got = serve_all(|q| router.submit(q), &queries)?;
        for (qi, (a, b)) in base.iter().zip(&got).enumerate() {
            if a.ids != b.ids || a.scores != b.scores || a.reduced != b.reduced {
                return Err(format!(
                    "routed({net})/{seam} topm:{} answers differ from the unrouted \
                     control on query {qi}",
                    t.route_m
                ));
            }
        }
        let st = router.settled_stats(SETTLE);
        // routed scatters run fetch-after-merge: exactly k stage-2 reads
        // per query, routing or not
        if st.ssd_reads != t.n_queries as u64 * k {
            return Err(format!(
                "routed({net})/{seam} issued {} stage-2 reads, want {}",
                st.ssd_reads,
                t.n_queries as u64 * k
            ));
        }
        // full coverage through either net: every query cost n_parts legs
        let want_legs = (t.n_queries * t.n_parts) as u64;
        if st.routed_shards != want_legs {
            return Err(format!(
                "routed({net})/{seam} dispatched {} stage-1 legs, want {want_legs}",
                st.routed_shards
            ));
        }
        // the nets only exist when the plan is actually selective
        let selective = t.route_m < t.n_parts;
        let want_probes = if selective && net == "all-probes" { t.n_queries as u64 } else { 0 };
        let want_esc =
            if selective && net == "all-escalations" { t.n_queries as u64 } else { 0 };
        if st.probes != want_probes || st.escalations != want_esc {
            return Err(format!(
                "routed({net})/{seam} counted {} probes / {} escalations, \
                 want {want_probes} / {want_esc}",
                st.probes, st.escalations
            ));
        }
    }
    Ok(())
}

#[test]
fn randomized_router_equivalence_and_io_accounting() {
    Prop::new("router-equivalence").cases(20).run(gen_trial, check_trial);
}

/// The acceptance bar, measured from MQSim-Next device counters
/// (`SimStats::stage2_reads`) rather than coordinator bookkeeping: with
/// real simulated devices behind every partition, `--fetch merge` must
/// return bit-identical answers AND issue ≤ speculative/(N−0.5) stage-2
/// device reads for N ∈ {2, 4}.
#[test]
fn after_merge_cuts_sim_device_stage2_reads_nx() {
    let corpus = Arc::new(ServingCorpus::synthetic(4, 1913));
    let mut qrng = Rng::new(313);
    let n_queries = 3usize;
    let queries: Vec<Vec<f32>> = (0..n_queries)
        .map(|_| corpus.query_near(qrng.below(corpus.n as u64) as usize, 0.02, &mut qrng))
        .collect();
    let single = start_single(&corpus).unwrap();
    let base = serve_all(|q| single.submit(q), &queries).unwrap();

    for n in [2usize, 4] {
        let mut reads_by_mode = Vec::new();
        for fetch in [FetchMode::Speculative, FetchMode::AfterMerge] {
            let router =
                start_router(&corpus, n, &BackendSpec::small_sim(4096), fetch, None).unwrap();
            let got = serve_all(|q| router.submit(q), &queries).unwrap();
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.ids, b.ids, "{} N={n}: ids differ", fetch.name());
                assert_eq!(a.scores, b.scores, "{} N={n}: scores differ", fetch.name());
                assert_eq!(a.reduced, b.reduced, "{} N={n}: reduced differ", fetch.name());
            }
            let st = router.settled_stats(SETTLE);
            let dev = st
                .storage
                .as_ref()
                .and_then(|s| s.device.as_ref())
                .expect("sim workers expose merged device stats")
                .clone();
            reads_by_mode.push(dev.stage2_reads);
        }
        let (spec_reads, merge_reads) = (reads_by_mode[0], reads_by_mode[1]);
        let k = SERVE.topk as u64;
        assert_eq!(spec_reads, n_queries as u64 * k * n as u64, "N={n} speculative");
        assert_eq!(merge_reads, n_queries as u64 * k, "N={n} after-merge");
        // the ISSUE acceptance inequality, from device-level counters
        assert!(
            (merge_reads as f64) <= spec_reads as f64 / (n as f64 - 0.5),
            "N={n}: after-merge {merge_reads} reads !<= speculative {spec_reads}/(N-0.5)"
        );
    }
}

/// The governed seams resolve shed plans identically: for every forced
/// rung × fetch protocol, the threaded `partitioned_overload` router and
/// the reactor `partitioned_reactor_overload` router must return
/// bit-identical answers — including the *degraded* ones (shrunk promote
/// set at `ShrinkK`, reduced-score-only at `Stage1Only`). Both seams now
/// route their plans through the same `resolve_dispatch` helper; this
/// test is the pin that keeps them from drifting apart again.
#[test]
fn governed_seams_degrade_bit_identically() {
    use fivemin::coordinator::{OverloadConfig, Rung, SloConfig};

    let corpus = Arc::new(ServingCorpus::synthetic(2, 733));
    let mut qrng = Rng::new(409);
    let queries: Vec<Vec<f32>> = (0..3)
        .map(|_| corpus.query_near(qrng.below(corpus.n as u64) as usize, 0.02, &mut qrng))
        .collect();

    // Inert guardrails (unreachable SLOs, effectively-infinite window):
    // the only rung in play is the one we force, so the comparison
    // isolates plan *resolution* from ladder dynamics.
    let slo = SloConfig { p50_us: 1e12, p95_us: 1e12, p99_us: 1e12, max_queue_depth: 1 << 20 };
    let ocfg = OverloadConfig { window: 1 << 30, shrink_k: 4, ..OverloadConfig::for_slo(slo) };

    let make_workers = || -> Vec<Coordinator> {
        corpus
            .partitions(2)
            .unwrap()
            .into_iter()
            .map(|part| {
                Coordinator::start(
                    default_artifacts_dir(),
                    Arc::new(part),
                    BatchPolicy::default(),
                    BackendSpec::Mem,
                )
                .unwrap()
            })
            .collect()
    };

    for fetch in [FetchMode::Speculative, FetchMode::AfterMerge, FetchMode::Adaptive] {
        for rung in [Rung::Normal, Rung::ShrinkK, Rung::Stage1Only] {
            let threaded =
                Router::partitioned_overload(make_workers(), fetch, ocfg.clone(), None).unwrap();
            let reactor = Router::partitioned_reactor_overload(
                make_workers(),
                fetch,
                ReactorConfig::default(),
                ocfg.clone(),
                None,
            )
            .unwrap();
            threaded.overload().unwrap().force_rung(rung);
            reactor.overload().unwrap().force_rung(rung);
            let a = serve_all(|q| threaded.try_submit(q).expect("admitted"), &queries).unwrap();
            let b = serve_all(|q| reactor.try_submit(q).expect("admitted"), &queries).unwrap();
            for (qi, (x, y)) in a.iter().zip(&b).enumerate() {
                let tag = format!("{}/{} q{qi}", fetch.name(), rung.name());
                assert_eq!(x.ids, y.ids, "{tag}: ids differ across governed seams");
                assert_eq!(x.scores, y.scores, "{tag}: scores differ across governed seams");
                assert_eq!(x.reduced, y.reduced, "{tag}: reduced differ across governed seams");
            }
            if rung == Rung::ShrinkK {
                for (seam, got) in [("threads", &a), ("reactor", &b)] {
                    for r in got.iter() {
                        assert_eq!(
                            r.ids.len(),
                            ocfg.shrink_k,
                            "{seam}: ShrinkK must shrink the promote set"
                        );
                    }
                }
            }
        }
    }
}

/// Tenant-class arm of the seam-equivalence matrix: with tenant-aware
/// governance configured, both seams must stay bit-identical *per
/// tenant* — same classes, same per-tenant submission order → identical
/// deficit state (admission happens router-side via `try_admit_tenant`
/// in both seams, and the inert window means no decay) → identical
/// plans → identical answers. The test also pins the differentiation
/// itself: at a degraded rung the over-quota tenant's answers shrink
/// while within-quota tenants keep one rung of grace, identically on
/// both seams.
#[test]
fn governed_seams_stay_bit_identical_per_tenant_class() {
    use fivemin::coordinator::{OverloadConfig, Rung, SloConfig, TenantClass};

    let corpus = Arc::new(ServingCorpus::synthetic(2, 733));
    let mut qrng = Rng::new(977);
    let queries: Vec<Vec<f32>> = (0..24)
        .map(|_| corpus.query_near(qrng.below(corpus.n as u64) as usize, 0.02, &mut qrng))
        .collect();
    // tenant 0 hot (5 of every 8 submissions), 1..3 cold
    let tenant_of = |i: usize| -> u32 {
        match i % 8 {
            3 => 1,
            5 => 2,
            7 => 3,
            _ => 0,
        }
    };

    let slo = SloConfig { p50_us: 1e12, p95_us: 1e12, p99_us: 1e12, max_queue_depth: 1 << 20 };
    let ocfg = OverloadConfig {
        window: 1 << 30,
        shrink_k: 4,
        tenants: TenantClass::derive(4, 1.2),
        ..OverloadConfig::for_slo(slo)
    };

    let make_workers = || -> Vec<Coordinator> {
        corpus
            .partitions(2)
            .unwrap()
            .into_iter()
            .map(|part| {
                Coordinator::start(
                    default_artifacts_dir(),
                    Arc::new(part),
                    BatchPolicy::default(),
                    BackendSpec::Mem,
                )
                .unwrap()
            })
            .collect()
    };

    for rung in [Rung::Normal, Rung::ShrinkK, Rung::Stage1Only] {
        let threaded =
            Router::partitioned_overload(make_workers(), FetchMode::AfterMerge, ocfg.clone(), None)
                .unwrap();
        let reactor = Router::partitioned_reactor_overload(
            make_workers(),
            FetchMode::AfterMerge,
            ReactorConfig::default(),
            ocfg.clone(),
            None,
        )
        .unwrap();
        for r in [&threaded, &reactor] {
            // identical deficit warm-up on both controllers: tenant 0
            // past its capped fair share before any query is served
            let c = r.overload().unwrap();
            for _ in 0..16 {
                c.try_admit_tenant(0).expect("warm-up admission");
                c.on_complete_tenant(0, 1_000.0);
            }
            c.force_rung(rung);
        }
        let serve = |router: &Router| -> Vec<QueryResult> {
            let pending: Vec<_> = queries
                .iter()
                .enumerate()
                .map(|(i, q)| {
                    router.try_submit_tenant(q.clone(), tenant_of(i)).expect("admitted")
                })
                .collect();
            pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect()
        };
        let a = serve(&threaded);
        let b = serve(&reactor);
        for (qi, (x, y)) in a.iter().zip(&b).enumerate() {
            let tag = format!("{}/t{} q{qi}", rung.name(), tenant_of(qi));
            assert_eq!(x.ids, y.ids, "{tag}: ids differ across governed seams");
            assert_eq!(x.scores, y.scores, "{tag}: scores differ across governed seams");
            assert_eq!(x.reduced, y.reduced, "{tag}: reduced differ across governed seams");
        }
        // weighted shedding, pinned identically on both seams: the
        // over-quota tenant takes the rung, within-quota tenants get
        // one rung of grace
        for (seam, got) in [("threads", &a), ("reactor", &b)] {
            for (qi, r) in got.iter().enumerate() {
                let hot = tenant_of(qi) == 0;
                match rung {
                    Rung::Normal => assert_eq!(
                        r.ids.len(),
                        SERVE.topk,
                        "{seam} q{qi}: Normal serves everyone in full"
                    ),
                    Rung::ShrinkK => assert_eq!(
                        r.ids.len(),
                        if hot { ocfg.shrink_k } else { SERVE.topk },
                        "{seam} q{qi}: only the over-quota tenant shrinks"
                    ),
                    Rung::Stage1Only => {
                        assert_eq!(
                            r.ids.len(),
                            ocfg.shrink_k,
                            "{seam} q{qi}: promote set shrunk for all above ShrinkK"
                        );
                        if hot {
                            assert!(
                                r.scores.is_empty(),
                                "{seam} q{qi}: over-quota tenant gets stage-1-only"
                            );
                        } else {
                            assert!(
                                !r.scores.is_empty(),
                                "{seam} q{qi}: within-quota tenant keeps stage-2 scores"
                            );
                        }
                    }
                    _ => unreachable!(),
                }
            }
        }
    }
}

/// The tier across an explicit capacity sweep: from a tier that can hold
/// only a sliver of the promote traffic to one that holds everything,
/// answers stay bit-identical to the untiered single worker, and
/// `device reads == tier misses` holds exactly at every point — the
/// tier's effect is *which* reads reach the device, never *what* the
/// system answers.
#[test]
fn tiered_router_is_bit_identical_across_capacities() {
    let corpus = Arc::new(ServingCorpus::synthetic(2, 4451));
    let mut qrng = Rng::new(887);
    let queries: Vec<Vec<f32>> = (0..3)
        .map(|_| corpus.query_near(qrng.below(corpus.n as u64) as usize, 0.02, &mut qrng))
        .collect();
    let single = start_single(&corpus).unwrap();
    let base = serve_all(|q| single.submit(q), &queries).unwrap();
    for mb in [1u64, 4, 64] {
        for rule in [TierRule::Clock, TierRule::Breakeven, TierRule::FiveSec] {
            let spec = BackendSpec::Mem.tiered(TierSpec::new(mb, rule, 4096));
            let router = start_router(&corpus, 2, &spec, FetchMode::Speculative, None).unwrap();
            let got = serve_all(|q| router.submit(q), &queries).unwrap();
            for (a, b) in base.iter().zip(&got) {
                assert_eq!(a.ids, b.ids, "mb={mb} {}: ids differ", rule.name());
                assert_eq!(a.scores, b.scores, "mb={mb} {}: scores differ", rule.name());
                assert_eq!(a.reduced, b.reduced, "mb={mb} {}: reduced differ", rule.name());
            }
            let st = router.settled_stats(SETTLE);
            let snap = st.storage.as_ref().expect("storage snapshot");
            let ts = snap.stats.tier.as_ref().expect("tier stats");
            assert_eq!(
                st.ssd_reads,
                (queries.len() * 2 * SERVE.topk) as u64,
                "speculative submits N*k per query with or without the tier"
            );
            assert_eq!(ts.hits + ts.misses, st.ssd_reads, "mb={mb} {}", rule.name());
            assert_eq!(snap.stats.reads, ts.misses, "mb={mb} {}", rule.name());
        }
    }
}

/// The degenerate routing spec: a `topm:N` router holds nothing back, so
/// it must behave exactly like today's unrouted router on both seams —
/// bit-identical answers, the after-merge read cost, full-N stage-1
/// legs, and zero probes/escalations (nothing is ever skipped, so the
/// safety nets have nothing to do).
#[test]
fn routed_m_equals_n_matches_the_unrouted_router_bit_for_bit() {
    let n = 4usize;
    let corpus = Arc::new(ServingCorpus::synthetic_clustered(n, n, 3371));
    let mut qrng = Rng::new(811);
    let queries: Vec<Vec<f32>> = (0..6)
        .map(|_| corpus.query_near(qrng.below(corpus.n as u64) as usize, 0.02, &mut qrng))
        .collect();
    let control = start_router(&corpus, n, &BackendSpec::Mem, FetchMode::AfterMerge, None).unwrap();
    let base = serve_all(|q| control.submit(q), &queries).unwrap();
    for reactor in [None, Some(ReactorConfig::default())] {
        let seam = if reactor.is_some() { "reactor" } else { "threads" };
        let cfg = RouteConfig { heat_blend: 0.0, ..RouteConfig::top_m(n) };
        let router = start_routed(&corpus, n, &BackendSpec::Mem, cfg, reactor).unwrap();
        let got = serve_all(|q| router.submit(q), &queries).unwrap();
        for (qi, (a, b)) in base.iter().zip(&got).enumerate() {
            assert_eq!(a.ids, b.ids, "{seam} q{qi}: topm:N ids differ from unrouted");
            assert_eq!(a.scores, b.scores, "{seam} q{qi}: topm:N scores differ");
            assert_eq!(a.reduced, b.reduced, "{seam} q{qi}: topm:N reduced differ");
        }
        let st = router.settled_stats(SETTLE);
        assert_eq!(st.ssd_reads, (queries.len() * SERVE.topk) as u64, "{seam}: after-merge cost");
        assert_eq!(st.routed_shards, (queries.len() * n) as u64, "{seam}: full-N legs");
        assert_eq!((st.probes, st.escalations), (0, 0), "{seam}: no nets at M=N");
        assert_eq!(st.probe_recall, 1.0, "{seam}: unmeasured recall reads 1.0");
    }
}

/// The live-recall floor from ISSUE 10's acceptance bar: at `M = N/2` on
/// a clustered corpus under zipf traffic, the deterministic probes'
/// measured recall of the predicted-M subset against full fan-out must
/// clear 0.95 — while total stage-1 legs stay strictly below full
/// fan-out (the fan-out cut is real, not escalated away).
#[test]
fn selective_routing_holds_the_recall_floor_under_zipf() {
    use fivemin::util::rng::Zipf;

    let n = 4usize;
    let corpus = Arc::new(ServingCorpus::synthetic_clustered(n, n, 6089));
    // default heat_blend so the EWMA feed path is exercised end to end;
    // probes every 4th query give 16 recall samples over 64 queries
    let cfg = RouteConfig { probe_every: 4, ..RouteConfig::top_m(n / 2) };
    let router = start_routed(&corpus, n, &BackendSpec::Mem, cfg, None).unwrap();
    let zipf = Zipf::new(corpus.n, 1.1);
    let mut rng = Rng::new(0x51AB);
    let queries: Vec<Vec<f32>> = (0..64)
        .map(|_| {
            let t = zipf.sample(&mut rng).min(corpus.n - 1);
            corpus.query_near(t, 0.02, &mut rng)
        })
        .collect();
    serve_all(|q| router.submit(q), &queries).unwrap();
    let st = router.settled_stats(SETTLE);
    assert_eq!(st.probes, 16, "deterministic cadence: every 4th of 64 queries probes");
    assert!(
        st.probe_recall >= 0.95,
        "live probe recall {:.3} under the 0.95 floor at M=N/2",
        st.probe_recall
    );
    assert!(
        st.routed_shards < (queries.len() * n) as u64,
        "selective routing dispatched {} legs — no cut vs {} full fan-out",
        st.routed_shards,
        queries.len() * n
    );
    assert_eq!(st.ssd_reads, (queries.len() * SERVE.topk) as u64, "after-merge read cost holds");
}

/// KV GET equivalence through the migrated `BackedStore`: the same
/// blocked-Cuckoo workload over an untiered and a tier-fronted backend
/// returns identical GETs, with exact accounting — the tiered store's
/// `hits + misses` equals the untiered store's device reads, and its
/// device reads equal its misses.
#[test]
fn kv_gets_identical_through_tiered_backed_store() {
    use fivemin::kvstore::{BackedStore, CuckooParams, KvEngine, MemStore};
    use fivemin::util::rng::Zipf;

    let n_items = 3_000u64;
    let p = CuckooParams::for_capacity(n_items, 0.7, 512, 64);
    let run = |tier: Option<TierSpec>| {
        let mut spec = BackendSpec::Mem;
        if let Some(t) = tier {
            spec = spec.tiered(t);
        }
        let store = BackedStore::new(
            MemStore::new(p.n_buckets, p.slots_per_bucket),
            spec.build(),
        );
        let mut e = KvEngine::new(p, store, 128);
        for k in 1..=n_items {
            e.put(k, k.wrapping_mul(0x9E37_79B9));
        }
        e.flush();
        let zipf = Zipf::new(n_items as usize, 1.1);
        let mut rng = Rng::new(6161);
        let gets: Vec<Option<u64>> = (0..5_000)
            .map(|_| e.get(1 + zipf.sample(&mut rng) as u64))
            .collect();
        (gets, e.store.snapshot())
    };
    let (plain_gets, plain_snap) = run(None);
    for (mb, rule) in [(1u64, TierRule::Clock), (4, TierRule::Breakeven), (64, TierRule::Clock)] {
        let tier = TierSpec { rate: 1_000.0, ..TierSpec::new(mb, rule, 512) };
        let label = tier.label();
        let (gets, snap) = run(Some(tier));
        assert_eq!(gets, plain_gets, "{label}: GET results must not depend on the tier");
        let ts = snap.stats.tier.as_ref().expect("tier stats");
        assert_eq!(snap.stats.reads, ts.misses, "{label}: device reads == tier misses");
        assert_eq!(
            ts.hits + ts.misses,
            plain_snap.stats.reads,
            "{label}: every untiered device read became a hit or a miss"
        );
        assert_eq!(
            snap.stats.writes, plain_snap.stats.writes,
            "{label}: writes are write-through, tier or not"
        );
    }
}
