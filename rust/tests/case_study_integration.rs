//! Case-study integration: functional engines driven by the workload
//! generators, cross-checked against the analytical throughput models.

use fivemin::ann::{ann_throughput, AnnScenario, ProgressiveIndex};
use fivemin::config::{NandKind, PlatformConfig, PlatformKind, SsdConfig};
use fivemin::kvstore::{kv_throughput, CuckooParams, KvEngine, KvScenario, MemStore};
use fivemin::util::rng::{Rng, Zipf};

#[test]
fn kv_engine_cost_matches_fig8_assumptions() {
    // The Fig 8 model charges 1.5 reads per uncached GET and an amortized
    // RMW per PUT; the functional engine must not exceed those budgets.
    let n_items = 100_000u64;
    let params = CuckooParams::for_capacity(n_items, 0.7, 512, 64);
    let store = MemStore::new(params.n_buckets, params.slots_per_bucket);
    let mut engine = KvEngine::new(params, store, 256);
    for k in 1..=n_items {
        engine.put(k, k);
    }
    engine.flush();
    let r0 = engine.stats.ssd_reads;
    let mut rng = Rng::new(1);
    let gets = 50_000;
    for _ in 0..gets {
        engine.get(1 + rng.below(n_items));
    }
    let reads_per_get = (engine.stats.ssd_reads - r0) as f64 / gets as f64;
    assert!(
        reads_per_get <= 1.55,
        "engine reads/GET {reads_per_get} exceeds the model's 1.5 budget"
    );
}

#[test]
fn kv_no_data_loss_under_mixed_churn() {
    let n_items = 30_000u64;
    let params = CuckooParams::for_capacity(n_items, 0.7, 512, 64);
    let store = MemStore::new(params.n_buckets, params.slots_per_bucket);
    let mut engine = KvEngine::new(params, store, 128);
    let mut model = std::collections::HashMap::new();
    let zipf = Zipf::new(n_items as usize, 1.1);
    let mut rng = Rng::new(9);
    for i in 0..120_000u64 {
        let key = 1 + zipf.sample(&mut rng) as u64;
        if rng.bool(0.5) {
            engine.put(key, i);
            model.insert(key, i);
        } else if let Some(&want) = model.get(&key) {
            assert_eq!(engine.get(key), Some(want), "key {key} wrong value");
        }
    }
    engine.flush();
    // WAL drained: every check below reads from the bucket store
    for (&k, &v) in model.iter().take(5_000) {
        assert_eq!(engine.get(k), Some(v), "post-flush key {k}");
    }
    assert_eq!(engine.stats.failed_inserts, 0);
}

#[test]
fn ann_engine_promotion_economics_match_fig10_direction() {
    // Functional engine: more promotion => more full reads => better
    // recall; the Fig 10 model: more promotion => lower QPS. Together they
    // are the paper's quality/throughput trade-off.
    let mut rng = Rng::new(11);
    let d_full = 64;
    let data: Vec<Vec<f32>> = (0..3000)
        .map(|_| {
            let mut v: Vec<f32> = (0..d_full)
                .map(|i| rng.gaussian() as f32 / (1.0 + i as f32 * 0.1))
                .collect();
            let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            v.iter_mut().for_each(|x| *x /= n);
            v
        })
        .collect();
    let idx = ProgressiveIndex::build(data.clone(), 12, 8, 64, 12);
    let brute = |q: &[f32]| -> u32 {
        let mut best = (f32::MIN, 0u32);
        for (i, v) in data.iter().enumerate() {
            let s: f32 = q.iter().zip(v).map(|(a, b)| a * b).sum();
            if s > best.0 {
                best = (s, i as u32);
            }
        }
        best.1
    };
    let mut hits = [0u32; 2];
    let trials = 60;
    for _ in 0..trials {
        let mut q = data[rng.below(3000) as usize].clone();
        q.iter_mut().for_each(|x| *x += 0.05 * rng.gaussian() as f32);
        let truth = brute(&q);
        for (i, promote) in [4usize, 48].iter().enumerate() {
            let (res, cost) = idx.search(&q, 1, 96, *promote);
            assert_eq!(cost.full_reads as usize, *promote);
            if res[0].1 == truth {
                hits[i] += 1;
            }
        }
    }
    assert!(hits[1] >= hits[0], "more promotion must not hurt recall");

    // model side: heavier promotion costs QPS
    let gpu = PlatformConfig::preset(PlatformKind::GpuGddr);
    let sn = SsdConfig::storage_next(NandKind::Slc);
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    let light = ann_throughput(&AnnScenario::paper_default(2), &gpu, &sn, 128.0 * GB);
    let heavy = ann_throughput(&AnnScenario::paper_default(8), &gpu, &sn, 128.0 * GB);
    assert!(light.qps > heavy.qps);
}

#[test]
fn fig8_fig10_tables_consistent_with_models() {
    // The figure harness reports exactly what the models compute.
    let gpu = PlatformConfig::preset(PlatformKind::GpuGddr);
    let sn = SsdConfig::storage_next(NandKind::Slc);
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    let sc = KvScenario::paper_default(0.9, 1.2);
    let direct = kv_throughput(&sc, &gpu, &sn, 256.0 * GB).achievable / 1e6;
    let table = fivemin::figures::fig_casestudies::fig8().render();
    let line = table
        .lines()
        .find(|l| l.contains("90:10") && l.contains("strong") && l.contains("GPU") && l.contains("SN"))
        .unwrap();
    let cells: Vec<&str> = line.split('|').map(|c| c.trim()).collect();
    let reported: f64 = cells[8].parse().unwrap(); // 256GB column
    assert!((reported - direct).abs() < 0.1, "table {reported} vs model {direct}");
}
