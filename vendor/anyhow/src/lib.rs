//! Minimal offline stand-in for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! implements exactly the subset `fivemin` uses: [`Error`], [`Result`],
//! the [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Error values carry a rendered message plus an optional boxed
//! source for `Caused by:` chains in `Debug` output (what `fn main() ->
//! anyhow::Result<()>` prints on failure).
//!
//! Swapping back to the real crate is a one-line change in the root
//! `Cargo.toml`; no call sites need to change.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A rendered error message with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro's core).
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error { msg: msg.to_string(), source: None }
    }

    /// Prepend a higher-level context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The underlying cause, if this error wrapped one.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_ref().map(|e| &**e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut src = self.source();
        if src.is_some() {
            f.write_str("\n\nCaused by:")?;
        }
        while let Some(e) = src {
            write!(f, "\n    {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

// Like the real crate, `Error` deliberately does NOT implement
// `std::error::Error`, which keeps this blanket conversion coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let msg = e.to_string();
        Error { msg, source: Some(Box::new(e)) }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_debug_chain() {
        let e: Error = Error::from(io_err()).context("opening config");
        assert_eq!(e.to_string(), "opening config: gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn context_on_results_and_options() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading").unwrap_err();
        assert!(e.to_string().starts_with("reading: "));
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn macros() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Err(anyhow!("fell through with {}", x))
        }
        assert_eq!(inner(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(inner(7).unwrap_err().to_string(), "unlucky");
        assert_eq!(inner(1).unwrap_err().to_string(), "fell through with 1");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");
    }
}
