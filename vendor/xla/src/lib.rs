//! Offline stub of the `xla` crate (PJRT bindings).
//!
//! The build environment cannot fetch or link real XLA, so this crate
//! mirrors the handful of types/methods `fivemin::runtime` touches behind
//! its `pjrt` feature and makes every entry point return a clear runtime
//! error. Builds (and `cargo doc`) succeed with `--features pjrt`; actually
//! executing artifacts requires swapping the `xla` path dependency in the
//! root `Cargo.toml` for a real binding crate with the same surface.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type standing in for the real bindings' error.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT is unavailable — the `xla` dependency is the offline \
         stub (vendor/xla); point Cargo at a real xla binding crate"
    )))
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side tensor value.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper accepted by [`PjRtClient::compile`].
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}
