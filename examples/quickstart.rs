//! Quickstart: the paper's headline result in 40 lines.
//!
//! Computes the calibrated break-even interval (Eq. 1) for every
//! platform × device × block-size combination and shows the
//! minutes → seconds collapse.
//!
//!     cargo run --release --example quickstart

use fivemin::config::{IoMix, NandKind, PlatformConfig, PlatformKind, SsdConfig, BLOCK_SIZES};
use fivemin::model::economics;
use fivemin::util::table::{fmt_secs, Table};

fn main() {
    let mix = IoMix::paper_default(); // 90:10 reads, Phi_WA = 3

    println!("The 1987 five-minute rule said: cache anything re-used within ~5 minutes.");
    println!("With GPU hosts + Storage-Next SSDs the threshold is now measured in seconds:\n");

    let mut t = Table::new(
        "Calibrated break-even interval (SLC NAND)",
        &["platform", "device", "512B", "1KB", "2KB", "4KB"],
    );
    for pk in PlatformKind::all() {
        let plat = PlatformConfig::preset(pk);
        for (label, cfg) in [
            ("Normal SSD", SsdConfig::normal(NandKind::Slc)),
            ("Storage-Next", SsdConfig::storage_next(NandKind::Slc)),
        ] {
            let mut row = vec![plat.name().to_string(), label.to_string()];
            for &l in &BLOCK_SIZES {
                let be = economics::break_even(&plat, &cfg, l, mix);
                row.push(fmt_secs(be.total));
            }
            t.row(row);
        }
    }
    println!("{}", t.render());

    let gpu = PlatformConfig::preset(PlatformKind::GpuGddr);
    let cpu = PlatformConfig::preset(PlatformKind::CpuDdr);
    let sn = SsdConfig::storage_next(NandKind::Slc);
    let be_gpu = economics::break_even(&gpu, &sn, 512, mix);
    let be_cpu = economics::break_even(&cpu, &sn, 512, mix);
    println!(
        "512B records: CPU+DDR {} vs GPU+GDDR {} — a {:.1}x reduction; \
         {:.0}x below the classical five minutes.",
        fmt_secs(be_cpu.total),
        fmt_secs(be_gpu.total),
        be_cpu.total / be_gpu.total,
        300.0 / be_gpu.total,
    );
}
