//! END-TO-END DRIVER: two-stage progressive ANN serving through all three
//! layers (Sec VII-B / Fig 9).
//!
//!   L1  Pallas distance kernels  ──┐ lowered once by `make artifacts`
//!   L2  JAX two-stage graphs     ──┘ into artifacts/*.hlo.txt
//!   L3  this binary: router → dynamic batcher → PJRT execution,
//!       with the SSD cost of every promoted fetch accounted through the
//!       analytical device model.
//!
//! Run (after `make artifacts && cargo build --release`):
//!     cargo run --release --example ann_serving
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;
use std::time::Instant;

use fivemin::ann::{ann_throughput, AnnScenario};
use fivemin::config::{NandKind, PlatformConfig, PlatformKind, SsdConfig};
use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{Coordinator, Router, ServingCorpus};
use fivemin::runtime::{default_artifacts_dir, SERVE};
use fivemin::util::rng::Rng;
use fivemin::util::table::fmt_secs;

fn main() -> anyhow::Result<()> {
    let dir = default_artifacts_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // ---- corpus + serving stack ------------------------------------------
    let n_shards = 4;
    let corpus = Arc::new(ServingCorpus::synthetic(n_shards, 42));
    println!(
        "corpus: {} embeddings ({} reduced + {} full per vector), {} shards",
        corpus.n,
        512,
        4096,
        n_shards
    );
    println!("starting 2 workers (router round-robins across them)…");
    let w1 = Coordinator::start(dir.clone(), corpus.clone(), BatchPolicy::default())?;
    let w2 = Coordinator::start(dir, corpus.clone(), BatchPolicy::default())?;
    let router = Router::new(vec![w1, w2]);

    // ---- serve a batched query stream (concurrent submission) -------------
    let n_queries = 256;
    let mut rng = Rng::new(9);
    let t0 = Instant::now();
    let pending: Vec<_> = (0..n_queries)
        .map(|_| {
            let target = rng.below(corpus.n as u64) as usize;
            (target, router.submit(corpus.query_near(target, 0.02, &mut rng)))
        })
        .collect();
    let mut hits = 0usize;
    let mut served = 0usize;
    for (target, rx) in pending {
        let res = rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
        served += 1;
        if res.ids[0] as usize == target {
            hits += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();

    let stats = router.stats();
    let queries: u64 = stats.iter().map(|s| s.queries).sum();
    let batches: u64 = stats.iter().map(|s| s.batches).sum();
    println!("\n=== end-to-end serving results ===");
    println!("queries    : {served} in {dt:.2}s  ->  {:.0} QPS", served as f64 / dt);
    println!("recall@1   : {:.1}%", 100.0 * hits as f64 / served as f64);
    println!("batches    : {batches} ({:.1} queries/batch avg)", queries as f64 / batches as f64);
    for (i, s) in stats.iter().enumerate() {
        println!(
            "worker {i}   : {} queries, latency p50 {} p99 {}, stage1 p50 {}, stage2 p50 {}",
            s.queries,
            fmt_secs(s.latency_ns.percentile(0.5) / 1e9),
            fmt_secs(s.latency_ns.percentile(0.99) / 1e9),
            fmt_secs(s.stage1_ns.percentile(0.5) / 1e9),
            fmt_secs(s.stage2_ns.percentile(0.5) / 1e9),
        );
    }
    let ssd_reads: u64 = stats.iter().map(|s| s.ssd_reads).sum();
    println!("SSD fetches: {ssd_reads} promoted full vectors ({} per query)", SERVE.topk);

    // ---- what this workload costs at paper scale --------------------------
    println!("\n=== Fig 10 projection at paper scale (8G embeddings) ===");
    let gpu = PlatformConfig::preset(PlatformKind::GpuGddr);
    let sn = SsdConfig::storage_next(NandKind::Slc);
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    for kb in [2u64, 4, 6, 8] {
        let sc = AnnScenario::paper_default(kb);
        let small = ann_throughput(&sc, &gpu, &sn, 32.0 * GB);
        let large = ann_throughput(&sc, &gpu, &sn, 512.0 * GB);
        println!(
            "  512B->{kb}KB ({:.0}% promoted): {:>5.1} KQPS @32GB -> {:>5.1} KQPS @512GB ({})",
            sc.promote_frac * 100.0,
            small.qps / 1e3,
            large.qps / 1e3,
            large.limiter
        );
    }
    println!("\nDiskANN-class systems report ~5 KQPS at billion scale; GPU+Storage-Next");
    println!("pushes toward tens of KQPS while keeping HNSW-level recall.");
    Ok(())
}
