//! END-TO-END DRIVER: two-stage progressive ANN serving through all three
//! layers (Sec VII-B / Fig 9), with the corpus *partitioned* across
//! workers — each owns a disjoint slice of the shards on its own storage
//! device — and a scatter/gather router merging per-partition top-k into
//! the global answer.
//!
//!   L1  Pallas distance kernels  ──┐ lowered once by `make artifacts`
//!   L2  JAX two-stage graphs     ──┘ (native Rust engine runs the same
//!                                     math when artifacts are absent)
//!   L3  this binary: scatter/gather router → per-partition dynamic
//!       batcher → graph execution, with every promoted fetch charged to
//!       the owning shard's `storage::StorageBackend`.
//!
//! Run:
//!     cargo run --release --example ann_serving -- --backend mem
//!     cargo run --release --example ann_serving -- --backend model
//!     cargo run --release --example ann_serving -- --backend sim
//!     cargo run --release --example ann_serving -- --backend sim --workers 2
//!     cargo run --release --example ann_serving -- --backend sim --pace wall:50
//!     cargo run --release --example ann_serving -- --backend sim --fetch merge
//!     cargo run --release --example ann_serving -- --backend sim --fetch adaptive
//!     cargo run --release --example ann_serving -- --backend sim --slo-p99-us 5000
//!     cargo run --release --example ann_serving -- --serve reactor --queries 5000
//!     cargo run --release --example ann_serving -- --backend uring --serve reactor
//!     cargo run --release --example ann_serving -- --backend sim --route topm:2
//!     cargo run --release --example ann_serving -- --serve reactor --route topm:2
//!
//! `mem` reproduces the DRAM-resident baseline; `model` charges the
//! analytic Eq. 2 + queueing cost; `sim` replays the fetch traffic on
//! MQSim-Next in virtual time and reports device-level stats.
//! `--pace wall:S` slows the simulator to S virtual seconds per wall
//! second so you can watch the device be the bottleneck in real time.
//! `--fetch merge` switches the router to the two-phase fetch-after-merge
//! protocol: stage-1 reduced scores merge first, then only the global
//! top-k is fetched from its owning shards — k device reads per query
//! instead of workers×k, at the cost of a second round-trip.
//! `--fetch adaptive` lets a load-feedback controller pick between the
//! two per dispatched query from the measured device stall vs phase-2
//! round-trip, with hysteresis (per-window decisions printed at the end).
//! `--tier dram:mb=N,rule=breakeven|5min|5s|clock` puts a DRAM tier in
//! front of every worker's device: repeated promoted reads are served
//! from DRAM when their reuse interval beats the rule's bar (the live
//! break-even interval by default) — device reads == tier misses,
//! answers bit-identical either way.
//! `--slo-p99-us US` puts the overload governor in front of the router:
//! a hard p99 latency budget with the shedding ladder behind it —
//! queries are admitted through `try_submit` and may be degraded or
//! rejected instead of queueing without bound (see `fivemin soak` for
//! the full drill).
//! `--serve reactor` swaps the merger+finisher-thread seam for the
//! completion-driven reactor: queries become small state machines
//! advanced by one event loop, with at most `--admission` tracked
//! in-flight at once (the rest wait in the inbox) and bit-identical
//! answers either way. Composes with every option above, including the
//! overload governor.
//! `--route topm:M` turns on heat-aware selective routing: an affinity
//! predictor (centroid sketch + contribution EWMA) sends each query's
//! stage-1 scan to only the top-M predicted shards instead of all N,
//! with weak-margin escalation and periodic full-fan-out probes as the
//! recall safety net. The corpus is clustered to align with the
//! partition cut (selective routing on an iid corpus has nothing to
//! exploit), fetch is forced to after-merge for routed queries, and the
//! routing line in the results reports the measured stage-1 legs/query
//! cut plus live probe recall. Under `--slo-p99-us` the shedding
//! ladder's early ShrinkM rung halves M before answer quality is
//! touched.

use std::sync::Arc;
use std::time::Instant;

use fivemin::ann::{ann_throughput, AnnScenario};
use fivemin::config::{NandKind, PlatformConfig, PlatformKind, SsdConfig};
use fivemin::coordinator::batcher::BatchPolicy;
use fivemin::coordinator::{
    AffinityPredictor, Coordinator, FetchMode, OverloadConfig, ReactorConfig, RouteConfig,
    RouteSpec, Router, ServingCorpus, SloConfig,
};
use fivemin::runtime::{default_artifacts_dir, SERVE};
use fivemin::storage::{BackendSpec, Pace, TierSpec};
use fivemin::util::cli::ArgSpec;
use fivemin::util::rng::Rng;
use fivemin::util::table::fmt_secs;

fn main() -> anyhow::Result<()> {
    let spec = ArgSpec::new("ann_serving", "two-stage partitioned ANN serving demo")
        .opt(
            "backend",
            "SPEC",
            Some("mem"),
            "per-partition storage backend: mem|model|sim[:shards=N]|uring[:path=FILE]",
        )
        .opt("queries", "N", Some("256"), "queries to issue")
        .opt(
            "workers",
            "N",
            Some("4"),
            "partition workers (must divide the 4 corpus shards)",
        )
        .opt(
            "pace",
            "afap|wall:S",
            Some("afap"),
            "sim pacing: as fast as possible, or S virtual seconds per wall second",
        )
        .opt(
            "fetch",
            "spec|merge|adaptive",
            Some("spec"),
            "stage-2 fetch protocol: speculative (1 round-trip), after-merge (2 round-trips, ~Nx fewer reads), or adaptive (picked per query from measured load)",
        )
        .opt(
            "tier",
            "none|dram:mb=N,rule=breakeven|5min|5s|clock",
            Some("none"),
            "per-worker DRAM tier in front of the device (admission by the live break-even rule by default)",
        )
        .opt(
            "slo-p99-us",
            "US",
            Some("0"),
            "govern admission with a hard p99 latency SLO (microseconds; 0 = ungoverned); over budget, the shedding ladder degrades then rejects",
        )
        .opt(
            "serve",
            "threads|reactor",
            Some("threads"),
            "scatter/gather seam: merger+finisher threads, or the completion-driven reactor event loop (bounded in-flight, no thread-per-query)",
        )
        .opt(
            "admission",
            "N",
            Some("4096"),
            "reactor admission window: max tracked in-flight queries (reactor seam only)",
        )
        .opt(
            "route",
            "all|topm:M",
            Some("all"),
            "stage-1 routing: full fan-out, or heat-aware selective routing to the top-M predicted shards (escalation + periodic full-fan-out probes keep recall honest; forces after-merge fetch for routed queries)",
        );
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = match spec.parse(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}\n\n{}", spec.usage());
            std::process::exit(2);
        }
    };
    let pace = Pace::parse(p.str("pace").unwrap())?;
    // Full ANN vectors are 4KB blocks on the device tier.
    let mut backend = BackendSpec::parse(p.str("backend").unwrap(), 4096)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .with_pace(pace);
    if let Some(tier) = TierSpec::parse(p.str("tier").unwrap(), 4096)? {
        backend = backend.tiered(tier);
    }
    let fetch = FetchMode::parse(p.str("fetch").unwrap())?;
    let slo_p99_us: f64 = p.f64("slo-p99-us").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let n_queries: usize = p.usize("queries").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let n_workers: usize = p.usize("workers").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
    let reactor = match p.str("serve").unwrap() {
        "threads" => None,
        "reactor" => {
            let admission = p.usize("admission").map_err(|e| anyhow::anyhow!("{e}"))?.unwrap();
            anyhow::ensure!(admission >= 1, "--admission must be >= 1");
            Some(ReactorConfig { admission, ..ReactorConfig::default() })
        }
        other => anyhow::bail!("unknown serve seam '{other}' (want threads|reactor)"),
    };
    let route = RouteSpec::parse(p.str("route").unwrap())?;
    let routed = matches!(route, RouteSpec::TopM(_));

    // ---- corpus + serving stack ------------------------------------------
    let dir = default_artifacts_dir();
    let n_shards = 4;
    // Selective routing demos a clustered corpus (clusters aligned with
    // the partition cut) — on an iid corpus every shard is equally
    // relevant and cutting fan-out necessarily costs recall.
    let corpus = Arc::new(if routed {
        ServingCorpus::synthetic_clustered(n_shards, n_shards, 42)
    } else {
        ServingCorpus::synthetic(n_shards, 42)
    });
    println!(
        "corpus: {} embeddings ({} reduced + {} full bytes per vector), {} shards",
        corpus.n, 512, 4096, n_shards
    );
    println!(
        "starting {n_workers} partition workers on the '{}' storage backend \
         (scatter/gather router, '{}' stage-2 fetch, '{}' serving seam, '{}' routing)…",
        backend.kind().name(),
        fetch.name(),
        if reactor.is_some() { "reactor" } else { "threads" },
        route.name()
    );
    let parts = corpus.partitions(n_workers)?;
    let pred = if routed {
        Some(Arc::new(AffinityPredictor::from_partitions(
            &parts,
            RouteConfig { spec: route, ..RouteConfig::default() },
        )?))
    } else {
        None
    };
    let workers = parts
        .into_iter()
        .map(|part| {
            // each partition's device holds exactly its slice of vectors
            let spec = backend.clone().for_capacity(part.n as u64);
            Coordinator::start(dir.clone(), Arc::new(part), BatchPolicy::default(), spec)
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let router = if slo_p99_us > 0.0 {
        let slo = SloConfig {
            p50_us: 0.25 * slo_p99_us,
            p95_us: 0.5 * slo_p99_us,
            p99_us: slo_p99_us,
            max_queue_depth: 4 * SERVE.batch,
        };
        let ocfg = OverloadConfig::for_slo(slo);
        match (reactor, pred) {
            (Some(cfg), Some(p)) => {
                Router::partitioned_reactor_overload_routed(workers, fetch, cfg, ocfg, None, p)?
            }
            (Some(cfg), None) => {
                Router::partitioned_reactor_overload(workers, fetch, cfg, ocfg, None)?
            }
            (None, Some(p)) => Router::partitioned_overload_routed(workers, fetch, ocfg, None, p)?,
            (None, None) => Router::partitioned_overload(workers, fetch, ocfg, None)?,
        }
    } else {
        match (reactor, pred) {
            (Some(cfg), Some(p)) => Router::partitioned_reactor_routed(workers, fetch, cfg, p)?,
            (Some(cfg), None) => Router::partitioned_reactor(workers, fetch, cfg)?,
            (None, Some(p)) => Router::partitioned_routed(workers, fetch, p)?,
            (None, None) => Router::partitioned_with(workers, fetch)?,
        }
    };

    // ---- serve a batched query stream (concurrent submission) -------------
    let mut rng = Rng::new(9);
    let t0 = Instant::now();
    let mut rejected = 0usize;
    let pending: Vec<_> = (0..n_queries)
        .filter_map(|_| {
            let target = rng.below(corpus.n as u64) as usize;
            let query = corpus.query_near(target, 0.02, &mut rng);
            // ungoverned routers admit everything; governed ones may shed
            match router.try_submit(query) {
                Ok(rx) => Some((target, rx)),
                Err(_) => {
                    rejected += 1;
                    None
                }
            }
        })
        .collect();
    let mut hits = 0usize;
    let mut served = 0usize;
    for (target, rx) in pending {
        let res = rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
        served += 1;
        if res.ids[0] as usize == target {
            hits += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();

    let stats = router.stats();
    let merged = router.merged_stats();
    println!("\n=== end-to-end serving results ===");
    println!("queries    : {served} in {dt:.2}s  ->  {:.0} QPS", served as f64 / dt);
    println!("recall@1   : {:.1}%", 100.0 * hits as f64 / served as f64);
    println!(
        "batches    : {} across partitions ({:.1} requests/batch avg)",
        merged.batches,
        (merged.queries + merged.reduce_legs + merged.fetch_legs) as f64
            / merged.batches.max(1) as f64
    );
    let e2e = router.gather_latency();
    println!(
        "end-to-end : merged-answer latency p50 {} p99 {}",
        fmt_secs(e2e.percentile(0.5) / 1e9),
        fmt_secs(e2e.percentile(0.99) / 1e9),
    );
    if routed {
        println!(
            "routing    : {:.2} stage-1 legs/query (vs {} full fan-out), {} escalations, \
             {} probes (live recall {:.2})",
            merged.routed_shards as f64 / served.max(1) as f64,
            router.n_workers(),
            merged.escalations,
            merged.probes,
            merged.probe_recall
        );
    }
    if let Some(rep) = router.reactor_report() {
        println!(
            "reactor    : {} admitted / {} completed, peak pending {} (window {})",
            rep.admitted, rep.completed, rep.peak_pending, rep.admission
        );
    }
    if let Some(rep) = router.overload_report() {
        println!(
            "overload   : {} admitted / {} rejected ({rejected} at submit), rung '{}' \
             ({} escalations, {} de-escalations)",
            rep.admitted,
            rep.rejected,
            rep.rung.name(),
            rep.escalations,
            rep.de_escalations,
        );
    }
    if let Some(rep) = router.adaptive_report() {
        println!(
            "adaptive   : {} spec / {} merge dispatches, {} flips, final mode '{}'",
            rep.spec_queries,
            rep.merge_queries,
            rep.flips,
            rep.mode.name(),
        );
        for w in &rep.windows {
            println!(
                "  window {:>3}: {:<5} spec-cost {:>9.1}us vs merge-cost {:>9.1}us{}",
                w.index,
                w.mode.name(),
                w.spec_cost_ns / 1e3,
                w.merge_cost_ns / 1e3,
                if w.flipped { "  << flip" } else { "" }
            );
        }
    }
    for (i, s) in stats.iter().enumerate() {
        if s.queries > 0 {
            println!(
                "partition {i}: {} queries, latency p50 {} p99 {}, stage1 p50 {}, stage2 p50 {}",
                s.queries,
                fmt_secs(s.latency_ns.percentile(0.5) / 1e9),
                fmt_secs(s.latency_ns.percentile(0.99) / 1e9),
                fmt_secs(s.stage1_ns.percentile(0.5) / 1e9),
                fmt_secs(s.stage2_ns.percentile(0.5) / 1e9),
            );
        } else {
            // two-phase mode: the worker served reduce/fetch legs instead
            println!(
                "partition {i}: {} reduce + {} fetch legs, stage1 p50 {}, stage2 p50 {}",
                s.reduce_legs,
                s.fetch_legs,
                fmt_secs(s.stage1_ns.percentile(0.5) / 1e9),
                fmt_secs(s.stage2_ns.percentile(0.5) / 1e9),
            );
        }
        println!(
            "  storage  : burst stall p50 {} p99 {}",
            fmt_secs(s.storage_stall_ns.percentile(0.5) / 1e9),
            fmt_secs(s.storage_stall_ns.percentile(0.99) / 1e9),
        );
        if let Some(snap) = &s.storage {
            println!(
                "  backend  : {} — {} reads, device read p50 {} p99 {}",
                snap.kind.name(),
                snap.stats.reads,
                fmt_secs(snap.stats.read_device_ns.percentile(0.5) / 1e9),
                fmt_secs(snap.stats.read_device_ns.percentile(0.99) / 1e9),
            );
            if let Some(dev) = &snap.device {
                println!(
                    "  device   : {:.2}M IOPS in device time, read p99 {} (MQSim-Next), \
                     {} senses",
                    dev.read_iops() / 1e6,
                    fmt_secs(dev.read_lat.percentile(0.99) / 1e9),
                    dev.host_senses,
                );
            }
        }
    }
    if let Some(snap) = &merged.storage {
        // a partition worker's backend may itself be sharded over several
        // devices — count the actual device fleet, not the workers
        let n_devices: usize = snap.shards.iter().map(|s| s.shards.len().max(1)).sum();
        println!(
            "aggregate  : {} device reads across {} devices ({} partitions), read p99 {}",
            snap.stats.reads,
            n_devices,
            snap.shards.len(),
            fmt_secs(snap.stats.read_device_ns.percentile(0.99) / 1e9),
        );
        if let Some(t) = &snap.stats.tier {
            println!("DRAM tier  : {}", t.summary());
        }
        if let Some(dev) = &snap.device {
            println!(
                "             {:.2}M aggregate device IOPS (capacity and IOPS scale together)",
                dev.read_iops() / 1e6,
            );
        }
    }
    println!(
        "SSD fetches: {} promoted full vectors ({:.1} per query; speculative \
         costs workers x {}, after-merge exactly {})",
        merged.ssd_reads,
        merged.ssd_reads as f64 / served.max(1) as f64,
        SERVE.topk,
        SERVE.topk
    );

    // ---- what this workload costs at paper scale --------------------------
    println!("\n=== Fig 10 projection at paper scale (8G embeddings) ===");
    let gpu = PlatformConfig::preset(PlatformKind::GpuGddr);
    let sn = SsdConfig::storage_next(NandKind::Slc);
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    for kb in [2u64, 4, 6, 8] {
        let sc = AnnScenario::paper_default(kb);
        let small = ann_throughput(&sc, &gpu, &sn, 32.0 * GB);
        let large = ann_throughput(&sc, &gpu, &sn, 512.0 * GB);
        println!(
            "  512B->{kb}KB ({:.0}% promoted): {:>5.1} KQPS @32GB -> {:>5.1} KQPS @512GB ({})",
            sc.promote_frac * 100.0,
            small.qps / 1e3,
            large.qps / 1e3,
            large.limiter
        );
    }
    println!("\nDiskANN-class systems report ~5 KQPS at billion scale; GPU+Storage-Next");
    println!("pushes toward tens of KQPS while keeping HNSW-level recall.");
    Ok(())
}
