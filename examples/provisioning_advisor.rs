//! Provisioning advisor: the Sec V workload-aware framework end to end.
//!
//! Takes the paper's Fig 6 workload (1e9 blocks, 200GB/s, log-normal
//! σ=1.2), walks both platforms through viability analysis at several DRAM
//! capacities, and prints the upgrade advice the framework produces.
//!
//!     cargo run --release --example provisioning_advisor

use fivemin::config::{IoMix, NandKind, PlatformConfig, PlatformKind, SsdConfig};
use fivemin::figures::fig_provisioning::tier90;
use fivemin::model::{platform as plat_model, upgrade};
use fivemin::util::table::{fmt_bytes, fmt_secs};
use fivemin::workload::LognormalProfile;

fn main() {
    let l_blk = 512u64;
    let mix = IoMix::paper_default();
    let profile = LognormalProfile::calibrated(200e9, 1.2, 1e9, l_blk);
    println!(
        "workload: 1e9 x {l_blk}B blocks ({}), 200GB/s aggregate, sigma=1.2\n",
        fmt_bytes(1e9 * l_blk as f64)
    );

    for pk in PlatformKind::all() {
        let plat = PlatformConfig::preset(pk);
        for cfg in [SsdConfig::normal(NandKind::Slc), SsdConfig::storage_next(NandKind::Slc)] {
            let Some(pr) = plat_model::provision(&profile, &plat, &cfg, mix, tier90(l_blk))
            else {
                println!("{} + {}: infeasible at any DRAM capacity", plat.name(), cfg.name);
                continue;
            };
            println!("=== {} + {} ===", plat.name(), cfg.name);
            println!(
                "  thresholds: T_B={} T_S={} tau_be={}",
                fmt_secs(pr.t_b),
                fmt_secs(pr.t_s),
                fmt_secs(pr.break_even.total)
            );
            println!(
                "  min viable DRAM: {:>9}   economics-optimal DRAM: {:>9}",
                fmt_bytes(pr.cap_viable),
                fmt_bytes(pr.cap_optimal)
            );

            // what does the advisor say at half the viable capacity?
            let advice = upgrade::advise(
                &profile, &plat, &cfg, mix, tier90(l_blk), pr.cap_viable * 0.5,
            );
            println!(
                "  at {} DRAM: viable={} -> {:?}",
                fmt_bytes(pr.cap_viable * 0.5),
                advice.verdict.viable,
                advice.recommendations[0]
            );
            // and at the optimum?
            let advice = upgrade::advise(
                &profile, &plat, &cfg, mix, tier90(l_blk), pr.cap_optimal * 1.05,
            );
            println!(
                "  at {} DRAM: viable={} optimal={}\n",
                fmt_bytes(pr.cap_optimal * 1.05),
                advice.verdict.viable,
                advice.verdict.economics_optimal
            );
        }
    }
}
