//! SSD-resident KV store demo (Sec VII-A): the functional blocked-Cuckoo
//! engine running a YCSB-style mixed workload with DRAM hot-pair caching
//! and WAL consolidation, followed by the paper-scale Fig 8 projection.
//!
//!     cargo run --release --example kv_store_demo

use fivemin::config::{NandKind, PlatformConfig, PlatformKind, SsdConfig};
use fivemin::kvstore::{
    kv_throughput, CuckooParams, KvEngine, KvScenario, MemStore,
};
use fivemin::util::rng::{Rng, Zipf};
use fivemin::util::table::{fmt_si, Table};

fn main() {
    // ---- functional engine at demo scale --------------------------------
    let n_items = 200_000u64;
    let params = CuckooParams::for_capacity(n_items, 0.7, 512, 64);
    let store = MemStore::new(params.n_buckets, params.slots_per_bucket);
    let mut engine = KvEngine::new(params, store, 20_000, 512);

    println!("loading {n_items} items into the blocked-Cuckoo store…");
    for k in 1..=n_items {
        engine.put(k, k.wrapping_mul(0x9E37_79B9));
    }
    engine.flush();

    println!("running 500k ops of 90:10 GET:PUT with zipf(1.1) popularity…");
    let zipf = Zipf::new(n_items as usize, 1.1);
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let ops = 500_000u64;
    for i in 0..ops {
        let key = 1 + zipf.sample(&mut rng) as u64;
        if rng.bool(0.9) {
            let v = engine.get(key);
            assert!(v.is_some(), "key {key} lost");
        } else {
            engine.put(key, i);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let st = engine.stats;
    println!("  engine throughput : {} ops/s (in-process, correctness-focused)", fmt_si(ops as f64 / dt));
    println!("  cache hit rate    : {:.1}%", 100.0 * engine.cache.hit_rate());
    println!("  SSD I/Os per op   : {:.3} ({} reads, {} writes)",
        engine.ios_per_op(), st.ssd_reads, st.ssd_writes);
    println!("  WAL appends/flushes: {} / {}", st.wal_appends, st.flushes);
    println!("  failed inserts    : {}\n", st.failed_inserts);

    // ---- paper-scale projection (Fig 8) ----------------------------------
    println!("Fig 8 projection — 5TB store (80G x 64B), strong locality:");
    let mut t = Table::new(
        "achievable Mops/s by platform/device and DRAM capacity",
        &["config", "64GB", "256GB", "512GB"],
    );
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    for (pname, pk) in [("CPU", PlatformKind::CpuDdr), ("GPU", PlatformKind::GpuGddr)] {
        let plat = PlatformConfig::preset(pk);
        for (dname, cfg) in [
            ("NR", SsdConfig::normal(NandKind::Slc)),
            ("SN", SsdConfig::storage_next(NandKind::Slc)),
        ] {
            let sc = KvScenario::paper_default(0.9, 1.2);
            let mut row = vec![format!("{pname}+{dname}")];
            for cap in [64.0, 256.0, 512.0] {
                let r = kv_throughput(&sc, &plat, &cfg, cap * GB);
                row.push(format!("{:.0}M ({})", r.achievable / 1e6, r.limiter));
            }
            t.row(row);
        }
    }
    println!("{}", t.render());
    println!("GPU + Storage-Next sustains 100+ Mops/s — in-memory-KV-class \
              throughput from an SSD-resident store.");
}
