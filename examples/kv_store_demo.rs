//! SSD-resident KV store demo (Sec VII-A): the functional blocked-Cuckoo
//! engine running a YCSB-style mixed workload with WAL consolidation —
//! every bucket access and log append charged to a pluggable storage
//! backend, hot buckets held in DRAM by the economics-governed storage
//! tier — followed by the paper-scale Fig 8 projection.
//!
//!     cargo run --release --example kv_store_demo -- --backend mem
//!     cargo run --release --example kv_store_demo -- --backend model
//!     cargo run --release --example kv_store_demo -- --backend sim
//!     cargo run --release --example kv_store_demo -- --tier dram:mb=16,rule=5s
//!     cargo run --release --example kv_store_demo -- --tier none
//!
//! `mem` is the in-process baseline; `model` prices each I/O with the
//! analytic Eq. 2 + queueing model; `sim` replays the block traffic on
//! MQSim-Next in virtual time (fewer ops, device-level stats reported).
//! `--tier` sizes the DRAM bucket tier and picks its admission rule —
//! the paper's break-even interval by default (the engine's old ad-hoc
//! `KvCache` is gone; placement is the tier's decision now).

use fivemin::config::{NandKind, PlatformConfig, PlatformKind, SsdConfig};
use fivemin::kvstore::{
    kv_throughput, BackedStore, CuckooParams, KvEngine, KvScenario, MemStore,
};
use fivemin::storage::{BackendKind, BackendSpec, TierSpec};
use fivemin::util::cli::ArgSpec;
use fivemin::util::rng::{Rng, Zipf};
use fivemin::util::table::{fmt_si, Table};

fn main() {
    let spec = ArgSpec::new("kv_store_demo", "blocked-Cuckoo KV engine demo")
        .opt(
            "backend",
            "mem|model|sim",
            Some("mem"),
            "storage backend charged for bucket + WAL I/O",
        )
        .opt(
            "tier",
            "none|dram:mb=N,rule=breakeven|5min|5s|clock",
            Some("dram:mb=8,rule=breakeven"),
            "DRAM bucket tier in front of the backend (admission by the live break-even rule)",
        );
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = match spec.parse(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}\n\n{}", spec.usage());
            std::process::exit(2);
        }
    };
    let backend = match BackendSpec::parse(p.str("backend").unwrap(), 512) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let tier = match TierSpec::parse(p.str("tier").unwrap(), 512) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };

    // ---- functional engine at demo scale --------------------------------
    // The simulator backend pays a full discrete-event pass per block I/O,
    // so scale the op count down while keeping the workload shape
    // (device_kind sees through a ':shards=N' wrapper: sharded-over-mem
    // stays at full scale, sharded-over-sim scales down).
    let (n_items, ops) = match backend.device_kind() {
        BackendKind::Sim => (20_000u64, 50_000u64),
        _ => (200_000u64, 500_000u64),
    };
    let params = CuckooParams::for_capacity(n_items, 0.7, 512, 64);
    // Fit a ':shards=N' spec's lba→device map to this store's address
    // space (buckets + WAL region) so the traffic actually spreads, then
    // put the DRAM tier in front of the whole (possibly sharded) device.
    let mut backend = backend.for_capacity(2 * params.n_buckets);
    if let Some(t) = tier.clone() {
        backend = backend.tiered(t);
    }
    let store = BackedStore::new(
        MemStore::new(params.n_buckets, params.slots_per_bucket),
        backend.build(),
    );
    let mut engine = KvEngine::new(params, store, 512);

    println!(
        "loading {n_items} items into the blocked-Cuckoo store ('{}' backend, tier {})…",
        backend.device_kind().name(),
        tier.as_ref().map(|t| t.label()).unwrap_or_else(|| "none".into())
    );
    for k in 1..=n_items {
        engine.put(k, k.wrapping_mul(0x9E37_79B9));
    }
    engine.flush();

    println!("running {ops} ops of 90:10 GET:PUT with zipf(1.1) popularity…");
    let zipf = Zipf::new(n_items as usize, 1.1);
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    for i in 0..ops {
        let key = 1 + zipf.sample(&mut rng) as u64;
        if rng.bool(0.9) {
            let v = engine.get(key);
            assert!(v.is_some(), "key {key} lost");
        } else {
            engine.put(key, i);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let st = engine.stats;
    println!(
        "  engine throughput : {} ops/s (wall clock, in-process)",
        fmt_si(ops as f64 / dt)
    );
    println!(
        "  SSD I/Os per op   : {:.3} ({} device reads, {} writes incl. WAL blocks)",
        engine.ios_per_op(),
        st.ssd_reads,
        st.ssd_writes
    );
    println!("  WAL appends/flushes: {} / {}", st.wal_appends, st.flushes);
    println!("  failed inserts    : {}", st.failed_inserts);

    // ---- per-backend device timing + unified tier snapshot ----------------
    let snap = engine.store.snapshot();
    if let Some(t) = &snap.stats.tier {
        println!("  DRAM tier         : {}", t.summary());
    }
    println!(
        "  device timing     : read p50 {:.1}us p99 {:.1}us, write-ack p50 {:.1}us",
        snap.stats.read_device_ns.percentile(0.5) / 1e3,
        snap.stats.read_device_ns.percentile(0.99) / 1e3,
        snap.stats.write_device_ns.percentile(0.5) / 1e3,
    );
    if let Some(dev) = &snap.device {
        println!(
            "  MQSim-Next        : {} reads / {} writes in device time, \
             {:.2}M IOPS, read p99 {:.1}us, {} GC erases",
            dev.reads_done,
            dev.writes_done,
            dev.iops() / 1e6,
            dev.read_lat.percentile(0.99) / 1e3,
            dev.erases,
        );
    }
    println!();

    // ---- paper-scale projection (Fig 8) ----------------------------------
    println!("Fig 8 projection — 5TB store (80G x 64B), strong locality:");
    let mut t = Table::new(
        "achievable Mops/s by platform/device and DRAM capacity",
        &["config", "64GB", "256GB", "512GB"],
    );
    const GB: f64 = 1024.0 * 1024.0 * 1024.0;
    for (pname, pk) in [("CPU", PlatformKind::CpuDdr), ("GPU", PlatformKind::GpuGddr)] {
        let plat = PlatformConfig::preset(pk);
        for (dname, cfg) in [
            ("NR", SsdConfig::normal(NandKind::Slc)),
            ("SN", SsdConfig::storage_next(NandKind::Slc)),
        ] {
            let sc = KvScenario::paper_default(0.9, 1.2);
            let mut row = vec![format!("{pname}+{dname}")];
            for cap in [64.0, 256.0, 512.0] {
                let r = kv_throughput(&sc, &plat, &cfg, cap * GB);
                row.push(format!("{:.0}M ({})", r.achievable / 1e6, r.limiter));
            }
            t.row(row);
        }
    }
    println!("{}", t.render());
    println!(
        "GPU + Storage-Next sustains 100+ Mops/s — in-memory-KV-class \
              throughput from an SSD-resident store."
    );
}
